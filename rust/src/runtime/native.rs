//! Native backend: evaluates the tensorized phase-domain ONN/TONN PINN
//! directly in rust — no python, no AOT artifacts, no XLA runtime.
//!
//! The evaluator is the rust mirror of `python/compile/networks.py` +
//! `python/compile/pinn.py` (checked against jax-computed goldens in
//! `rust/tests/artifact_numerics.rs`):
//!
//! * each SVD block `W = U(θ_U)·Σ·V(θ_V)^T` is materialized from the
//!   Givens/MZI mesh in [`crate::photonics::mesh`];
//! * TT layers reshape each block into its core tensor
//!   ([`TtCore::from_unfolding`]) and reconstruct the dense layer via
//!   [`crate::tensor::tt_dense`] — once per Φ, cached by exact phase
//!   vector and reused across the FD stencil fan-out and any repeated
//!   dispatch (the same amortization the artifacts perform);
//! * batches stream through the parallel evaluation engine: contiguous
//!   row-blocks hit the [`crate::tensor::gemm_rows`] micro-kernel and
//!   fan out across the persistent shared worker pool
//!   ([`super::parallel::for_row_blocks`] → [`super::pool`]),
//!   configured per dispatch by [`EvalOptions::parallel`] (falling back
//!   to the backend default the deprecated [`Backend::set_parallel`]
//!   shim still sets — which also steers the pool's global thread
//!   budget).
//!   Row-independent arithmetic makes the parallel path produce results
//!   identical to the sequential one for every config; the PR-1 scalar
//!   evaluator is retained as the reference oracle and bench baseline
//!   ([`NativeBackend::forward_reference`] /
//!   [`NativeBackend::loss_reference`]);
//! * the multi-Φ training entries (`loss_multi`, `loss_stein_multi`)
//!   are the batched loss API the ZO trainer dispatches once per epoch:
//!   K independent probe losses fan out across
//!   [`super::parallel::for_probes`] workers (the OUTER parallel level)
//!   while each probe's row blocks use the remaining thread budget —
//!   two-level parallelism under one `ParallelConfig`. Per-probe
//!   arithmetic is exactly the single-Φ loss, so probe-parallel ≡
//!   sequential bit for bit (`tests/probe_parallel.rs` checks every
//!   builtin preset in both FD and Stein modes);
//! * the BP-free FD / Stein losses and the validation MSE assemble PDE
//!   residuals through [`Problem::residual`]; problems with
//!   coordinate-weighted diffusion additionally receive per-dimension
//!   second-derivative estimates ([`Problem::needs_d2`]), and problems
//!   with soft constraints ([`crate::pde::SoftBoundary`]) get a weighted
//!   boundary MSE over deterministic projections of the collocation
//!   batch, evaluated in the same dispatch. The weight rides each
//!   dispatch ([`EvalOptions::bc_weight`]); the preset default (problem
//!   default → manifest `hyper.bc_weight`) remains runtime-tunable via
//!   the deprecated [`Backend::set_bc_weight`] shim.
//!
//! Presets come from an in-repo registry mirroring
//! `python/compile/model.py` ([`NativeBackend::builtin`]) or from a
//! `manifest.json` on disk ([`NativeBackend::load`]); either way the
//! parameter layout is rebuilt from the arch block and cross-checked.
//!
//! Everything here is plain data + atomics, so the backend is
//! `Send + Sync`: one instance can serve every solver-service worker.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{anyhow, Context, Result};

use super::parallel::{for_probes_capped, for_row_blocks, ParallelConfig, ParallelCtl};
use super::{
    Backend, Entry, EntryMeta, EvalOptions, EvalPrecision, FusedLossJob, FusedLossKind, Manifest,
    PresetMeta,
};
use crate::model::{Hyper, Layout, LayoutBuilder};
use crate::pde::Problem;
use crate::photonics::{mesh, noise};
use crate::tensor::{gemm_rows, simd, tt_dense, Mat, TtCore};
use crate::util::json::Value;
use crate::util::telemetry;

/// Batch shapes shared by all presets (mirrors `python/compile/model.py`).
pub const B_FWD: usize = 128;
pub const B_RES: usize = 100;
pub const B_VAL: usize = 1024;
pub const K_MULTI: usize = 11;

/// (offset, len) span into the flat parameter vector.
type Span = (usize, usize);

#[derive(Clone, Debug)]
struct SvdSpec {
    u: Span,
    s: Span,
    v: Span,
    m: usize,
    n: usize,
}

#[derive(Clone, Debug)]
struct CoreSpec {
    svd: SvdSpec,
    r_in: usize,
    m: usize,
    n: usize,
    r_out: usize,
}

#[derive(Clone, Debug)]
enum NetSpec {
    /// dense phase-domain MLP: two SVD blocks
    Onn {
        l1: SvdSpec,
        b1: Span,
        l2: SvdSpec,
        b2: Span,
    },
    /// TT-compressed MLP: per layer, one small SVD mesh per TT core
    Tonn {
        layers: Vec<(Vec<CoreSpec>, Span)>,
    },
}

/// Phase-domain network evaluator for one preset.
#[derive(Clone, Debug)]
struct NetEval {
    in_dim: usize,
    hidden: usize,
    omega0: f32,
    spec: NetSpec,
    w3: Span,
    b3: Span,
}

fn slice<'a>(phi: &'a [f32], s: Span) -> &'a [f32] {
    &phi[s.0..s.0 + s.1]
}

impl NetEval {
    fn svd_mat(&self, phi: &[f32], blk: &SvdSpec) -> Mat {
        mesh::svd_matrix(
            slice(phi, blk.u),
            slice(phi, blk.s),
            slice(phi, blk.v),
            blk.m,
            blk.n,
        )
    }

    /// Materialize layer `li`'s dense matrix + bias span for Φ.
    fn layer(&self, phi: &[f32], li: usize) -> (Mat, Span) {
        match &self.spec {
            NetSpec::Onn { l1, b1, l2, b2 } => {
                if li == 0 {
                    (self.svd_mat(phi, l1), *b1)
                } else {
                    (self.svd_mat(phi, l2), *b2)
                }
            }
            NetSpec::Tonn { layers } => {
                let (cores, bias) = &layers[li];
                let tt: Vec<TtCore> = cores
                    .iter()
                    .map(|c| {
                        TtCore::from_unfolding(
                            &self.svd_mat(phi, &c.svd),
                            c.r_in,
                            c.m,
                            c.n,
                            c.r_out,
                        )
                    })
                    .collect();
                (tt_dense(&tt), *bias)
            }
        }
    }

    /// Materialize every layer operand for one phase vector Φ: mesh ->
    /// SVD -> (TT ->) dense, transposed into the GEMM layout, plus the
    /// bias/readout values. Built once per Φ (see
    /// [`PresetEval::materialized`]) and shared read-only by every
    /// row-block worker — the "program the meshes once, stream the whole
    /// batch" amortization the photonic artifacts also perform.
    fn materialize(&self, phi: &[f32]) -> MaterializedNet {
        let layers = (0..2)
            .map(|li| {
                let (w, bias) = self.layer(phi, li);
                // activations act as y = x @ W^T
                (w.transpose(), slice(phi, bias).to_vec())
            })
            .collect();
        MaterializedNet::with_operands(layers, slice(phi, self.w3).to_vec(), phi[self.b3.0])
    }

    /// Evaluate rows `row0 .. row0 + out.len()` of the flat batch `xs`
    /// into `out` — the engine's unit of work. Per-row arithmetic is
    /// independent of the blocking, so any partition of the batch yields
    /// identical outputs (the parallel ≡ sequential contract).
    fn forward_block(&self, mat: &MaterializedNet, xs: &[f32], row0: usize, out: &mut [f32]) {
        let h = self.hidden;
        let d = self.in_dim;
        let nb = out.len();
        // input zero-padded UP to the layer fan-in
        let mut act = vec![0.0f32; nb * h];
        for r in 0..nb {
            act[r * h..r * h + d].copy_from_slice(&xs[(row0 + r) * d..(row0 + r + 1) * d]);
        }
        let mut z = vec![0.0f32; nb * h];
        for (li, (wt, bias)) in mat.layers.iter().enumerate() {
            // the padded input columns d..h are structurally zero on
            // layer 0: their W^T rows contribute nothing — skip them
            let k_used = if li == 0 { d } else { h };
            gemm_rows(&act, h, k_used, wt, &mut z);
            for r in 0..nb {
                let row = &mut z[r * h..(r + 1) * h];
                for (v, bb) in row.iter_mut().zip(bias) {
                    *v += *bb;
                }
                if li == 0 {
                    for v in row.iter_mut() {
                        *v = (self.omega0 * *v).sin();
                    }
                } else {
                    for v in row.iter_mut() {
                        *v = v.sin();
                    }
                }
            }
            std::mem::swap(&mut act, &mut z);
        }
        for r in 0..nb {
            let row = &act[r * h..(r + 1) * h];
            out[r] = row.iter().zip(&mat.w3).map(|(a, w)| a * w).sum::<f32>() + mat.b3;
        }
    }

    /// Raw network output f for a flat batch of rows (B·in_dim values):
    /// blocked GEMM over contiguous row-blocks, fanned out across the
    /// shared worker pool. Results are identical for every `par` value.
    fn forward_f(&self, mat: &MaterializedNet, xs: &[f32], par: ParallelConfig) -> Vec<f32> {
        let b = xs.len() / self.in_dim;
        let mut out = vec![0.0f32; b];
        for_row_blocks(par, 1, &mut out, |row0, block| {
            self.forward_block(mat, xs, row0, block);
        });
        out
    }

    /// [`Self::forward_block`] in the F64 oracle tier: f64 GEMM, f64
    /// sine activations and readout on the mirrored operands, cast to
    /// f32 per output row. Same row-block independence as the f32
    /// engine, so any blocking yields identical outputs.
    fn forward_block_f64(&self, net: &Net64, xs: &[f32], row0: usize, out: &mut [f32]) {
        let h = self.hidden;
        let d = self.in_dim;
        let nb = out.len();
        // input zero-padded UP to the layer fan-in
        let mut act = vec![0.0f64; nb * h];
        for r in 0..nb {
            for j in 0..d {
                act[r * h + j] = xs[(row0 + r) * d + j] as f64;
            }
        }
        let mut z = vec![0.0f64; nb * h];
        for (li, (wt, bias)) in net.layers.iter().enumerate() {
            let k_used = if li == 0 { d } else { h };
            simd::gemm_rows_f64(&act, h, k_used, wt, h, &mut z);
            for r in 0..nb {
                let row = &mut z[r * h..(r + 1) * h];
                for (v, bb) in row.iter_mut().zip(bias) {
                    *v += *bb;
                }
                if li == 0 {
                    for v in row.iter_mut() {
                        *v = (self.omega0 as f64 * *v).sin();
                    }
                } else {
                    for v in row.iter_mut() {
                        *v = v.sin();
                    }
                }
            }
            std::mem::swap(&mut act, &mut z);
        }
        for r in 0..nb {
            let row = &act[r * h..(r + 1) * h];
            out[r] = (simd::dot_f64(row, &net.w3) + net.b3) as f32;
        }
    }

    /// [`Self::forward_f`] in the F64 oracle tier (lazily mirrors the
    /// materialized operands to f64).
    fn forward_f64(&self, mat: &MaterializedNet, xs: &[f32], par: ParallelConfig) -> Vec<f32> {
        let net64 = mat.mirror64();
        let b = xs.len() / self.in_dim;
        let mut out = vec![0.0f32; b];
        for_row_blocks(par, 1, &mut out, |row0, block| {
            self.forward_block_f64(&net64, xs, row0, block);
        });
        out
    }

    /// The PR-1 scalar evaluator, retained verbatim: per-call layer
    /// materialization, whole-batch `Mat::matmul`, one thread. This is
    /// the correctness oracle the engine is tested against and the
    /// baseline its recorded speedups are measured from
    /// (`benches/latency.rs` -> `BENCH_native.json`).
    fn forward_f_reference(&self, phi: &[f32], xs: &[f32]) -> Vec<f32> {
        let h = self.hidden;
        let d = self.in_dim;
        let b = xs.len() / d;
        // input zero-padded UP to the layer fan-in
        let mut act = Mat::zeros(b, h);
        for r in 0..b {
            act.data[r * h..r * h + d].copy_from_slice(&xs[r * d..(r + 1) * d]);
        }
        for li in 0..2 {
            let (w, bias) = self.layer(phi, li);
            let wt = w.transpose(); // activations act as y = x @ W^T
            let mut z = act.matmul(&wt);
            let bs = slice(phi, bias);
            for r in 0..b {
                let row = &mut z.data[r * h..(r + 1) * h];
                for (v, bb) in row.iter_mut().zip(bs) {
                    *v += *bb;
                }
                if li == 0 {
                    for v in row.iter_mut() {
                        *v = (self.omega0 * *v).sin();
                    }
                } else {
                    for v in row.iter_mut() {
                        *v = v.sin();
                    }
                }
            }
            act = z;
        }
        let w3 = slice(phi, self.w3);
        let b3 = phi[self.b3.0];
        (0..b)
            .map(|r| {
                let row = &act.data[r * h..(r + 1) * h];
                row.iter().zip(w3).map(|(a, w)| a * w).sum::<f32>() + b3
            })
            .collect()
    }
}

/// Dense per-layer operands materialized from one phase vector Φ (the
/// engine's cached "programmed chip state"): per layer the transposed
/// dense matrix `W^T` in GEMM layout plus bias, and the readout.
///
/// Materialization itself (mesh → SVD → TT → dense) models the optical
/// hardware and always runs in f32; the precision tiers derive from the
/// f32 operands lazily — an f64 mirror for the
/// [`EvalPrecision::F64`] oracle, quantized weight variants for
/// [`EvalPrecision::Quantized`] — and are cached per materialized net so
/// the Φ-keyed MRU cache amortizes every tier at once.
#[derive(Debug)]
struct MaterializedNet {
    /// per hidden layer: (W^T with shape fan_in x fan_out, bias)
    layers: Vec<(Mat, Vec<f32>)>,
    w3: Vec<f32>,
    b3: f32,
    /// lazily-built f64 mirror backing the F64 oracle tier
    mirror64: OnceLock<Arc<Net64>>,
    /// MRU of weights-quantized variants keyed by bit depth (variants
    /// themselves carry empty tier caches — they are leaves)
    quant: Mutex<Vec<(u8, Arc<MaterializedNet>)>>,
}

/// MRU slots for quantized weight variants of one materialized net — a
/// bit-depth sweep on one Φ (the quantization ablation) touches a
/// handful of depths, not many.
const QUANT_CACHE_SLOTS: usize = 4;

impl MaterializedNet {
    fn with_operands(layers: Vec<(Mat, Vec<f32>)>, w3: Vec<f32>, b3: f32) -> MaterializedNet {
        MaterializedNet {
            layers,
            w3,
            b3,
            mirror64: OnceLock::new(),
            quant: Mutex::new(Vec::new()),
        }
    }

    /// The f64 mirror of the f32 operands, built once per materialized
    /// net and shared by every F64-tier dispatch on this Φ.
    fn mirror64(&self) -> Arc<Net64> {
        self.mirror64
            .get_or_init(|| {
                Arc::new(Net64 {
                    layers: self
                        .layers
                        .iter()
                        .map(|(wt, bias)| {
                            (
                                wt.data.iter().map(|&x| x as f64).collect(),
                                bias.iter().map(|&x| x as f64).collect(),
                            )
                        })
                        .collect(),
                    w3: self.w3.iter().map(|&x| x as f64).collect(),
                    b3: self.b3 as f64,
                })
            })
            .clone()
    }

    /// The weights-quantized variant of this net at `bits` (per-tensor
    /// symmetric quantization of every layer matrix, bias and readout —
    /// the DAC model; activations stay f32, see
    /// [`noise::quantize_symmetric`]). Cached per bit depth.
    fn quantized(self: &Arc<Self>, bits: u8) -> Arc<MaterializedNet> {
        {
            let mut q = self.quant.lock().unwrap();
            if let Some(i) = q.iter().position(|(b, _)| *b == bits) {
                let hit = q.remove(i);
                let m = hit.1.clone();
                q.insert(0, hit);
                return m;
            }
        }
        // build OUTSIDE the lock (same discipline as the Φ-keyed cache)
        let mut layers = self.layers.clone();
        for (wt, bias) in layers.iter_mut() {
            noise::quantize_symmetric(&mut wt.data, bits);
            noise::quantize_symmetric(bias, bits);
        }
        let mut w3 = self.w3.clone();
        noise::quantize_symmetric(&mut w3, bits);
        let m = Arc::new(MaterializedNet::with_operands(layers, w3, self.b3));
        let mut q = self.quant.lock().unwrap();
        if let Some(i) = q.iter().position(|(b, _)| *b == bits) {
            let hit = q.remove(i);
            let m = hit.1.clone();
            q.insert(0, hit);
            return m;
        }
        q.insert(0, (bits, m.clone()));
        q.truncate(QUANT_CACHE_SLOTS);
        m
    }
}

/// f64 mirror of a [`MaterializedNet`]'s operands (the F64 oracle tier):
/// same `W^T` GEMM layout, flat row-major data.
#[derive(Debug)]
struct Net64 {
    /// per hidden layer: (flat W^T data, shape fan_in x fan_out, bias)
    layers: Vec<(Vec<f64>, Vec<f64>)>,
    w3: Vec<f64>,
    b3: f64,
}

/// Build the evaluator + parameter layout from a manifest `arch` block
/// (the rust mirror of `OnnMlp.__init__` / `TonnMlp.__init__`).
fn build_net(arch: &Value) -> Result<(NetEval, Layout)> {
    let ty = arch
        .req("type")
        .map_err(|e| anyhow!("{e}"))?
        .as_str()
        .ok_or_else(|| anyhow!("arch.type must be a string"))?;
    let in_dim = arch
        .req("in_dim")
        .map_err(|e| anyhow!("{e}"))?
        .as_usize()
        .ok_or_else(|| anyhow!("arch.in_dim"))?;
    let omega0 = arch.get("omega0").and_then(|v| v.as_f64()).unwrap_or(6.0) as f32;
    let usizes = |key: &str| -> Result<Vec<usize>> {
        arch.req(key)
            .map_err(|e| anyhow!("{e}"))?
            .as_arr()
            .ok_or_else(|| anyhow!("arch.{key} must be an array"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("arch.{key} entry")))
            .collect()
    };
    match ty {
        "onn" => {
            let hidden = arch
                .req("hidden")
                .map_err(|e| anyhow!("{e}"))?
                .as_usize()
                .ok_or_else(|| anyhow!("arch.hidden"))?;
            anyhow::ensure!(hidden >= in_dim, "input is zero-padded UP to the fan-in");
            anyhow::ensure!(
                hidden >= 2 && hidden % 2 == 0,
                "mesh size must be even >= 2, got hidden = {hidden}"
            );
            let s0 = (6.0f64 / hidden as f64).sqrt();
            let mut lb = LayoutBuilder::new();
            let (u1, s1, v1) = lb.add_svd_block("l1", hidden, hidden, s0);
            let b1 = lb.add_weights("l1.bias", hidden, 0.1);
            let (u2, s2, v2) = lb.add_svd_block("l2", hidden, hidden, s0);
            let b2 = lb.add_weights("l2.bias", hidden, 0.1);
            let w3 = lb.add_weights("l3.w", hidden, 1.0 / (hidden as f64).sqrt());
            let b3 = lb.add_weights("l3.bias", 1, 0.0);
            let net = NetEval {
                in_dim,
                hidden,
                omega0,
                spec: NetSpec::Onn {
                    l1: SvdSpec { u: u1, s: s1, v: v1, m: hidden, n: hidden },
                    b1,
                    l2: SvdSpec { u: u2, s: s2, v: v2, m: hidden, n: hidden },
                    b2,
                },
                w3,
                b3,
            };
            Ok((net, lb.build()))
        }
        "tonn" => {
            let factors_m = usizes("factors_m")?;
            let factors_n = usizes("factors_n")?;
            let ranks = usizes("ranks")?;
            let l = factors_m.len();
            anyhow::ensure!(l >= 1 && factors_n.len() == l, "factor lists must match");
            anyhow::ensure!(
                ranks.len() == l + 1 && ranks[0] == 1 && ranks[l] == 1,
                "need L+1 ranks with boundary ranks 1"
            );
            let hidden: usize = factors_m.iter().product();
            let n_total: usize = factors_n.iter().product();
            anyhow::ensure!(hidden == n_total, "square TT layers only");
            anyhow::ensure!(hidden >= in_dim, "input is zero-padded UP to the fan-in");
            // per-core gain: the dense TT product multiplies L core gains
            let target = (6.0f64 / hidden as f64).sqrt();
            let core_gain = target.powf(1.0 / l as f64);
            let mut lb = LayoutBuilder::new();
            let mut layers = Vec::with_capacity(2);
            for li in 0..2 {
                let mut cores = Vec::with_capacity(l);
                for k in 0..l {
                    let a = ranks[k] * factors_n[k]; // mesh rows (r_in · n_k)
                    let b = factors_m[k] * ranks[k + 1]; // mesh cols (m_k · r_out)
                    anyhow::ensure!(
                        a >= 2 && a % 2 == 0 && b >= 2 && b % 2 == 0,
                        "core {k}: mesh unfolding {a}x{b} must have even dims >= 2 \
                         (r_in·n_k x m_k·r_out)"
                    );
                    let (u, s, v) =
                        lb.add_svd_block(&format!("tt{li}.core{k}"), a, b, core_gain);
                    cores.push(CoreSpec {
                        svd: SvdSpec { u, s, v, m: a, n: b },
                        r_in: ranks[k],
                        m: factors_m[k],
                        n: factors_n[k],
                        r_out: ranks[k + 1],
                    });
                }
                let bias = lb.add_weights(&format!("tt{li}.bias"), hidden, 0.1);
                layers.push((cores, bias));
            }
            let w3 = lb.add_weights("l3.w", hidden, 1.0 / (hidden as f64).sqrt());
            let b3 = lb.add_weights("l3.bias", 1, 0.0);
            let net = NetEval {
                in_dim,
                hidden,
                omega0,
                spec: NetSpec::Tonn { layers },
                w3,
                b3,
            };
            Ok((net, lb.build()))
        }
        other => Err(anyhow!("unknown arch type '{other}'")),
    }
}

/// All native evaluation for one preset: network + PDE loss assembly.
#[derive(Debug)]
pub struct PresetEval {
    problem: Arc<dyn Problem>,
    net: NetEval,
    fd_h: f32,
    stein_sigma: f32,
    stein_q: usize,
    /// DEFAULT soft-constraint boundary-loss weight (f32 bits; 0
    /// disables the term): what a dispatch resolves when its
    /// [`EvalOptions::bc_weight`] is `None`. Runtime-tunable through
    /// the deprecated [`Backend::set_bc_weight`] shim — only meaningful
    /// for problems with a [`crate::pde::SoftBoundary`].
    bc_weight: AtomicU32,
    /// DEFAULT engine parallelism, shared with the owning backend
    /// (runtime-tunable through the deprecated [`Backend::set_parallel`]
    /// shim); dispatches may override it via [`EvalOptions::parallel`]
    par: Arc<ParallelCtl>,
    /// MRU materialization cache keyed by exact phase vector: repeated
    /// dispatches with a recent Φ (validation sweeps, forward batches,
    /// bench loops, interleaved solver-service workers) skip the
    /// mesh -> SVD -> TT -> dense rebuild entirely
    mat_cache: Mutex<Vec<(Vec<f32>, Arc<MaterializedNet>)>>,
}

/// MRU slots in the per-preset materialization cache — sized to hold
/// the K = `K_MULTI` phase settings of one probe-parallel training
/// dispatch (so concurrent probes never evict each other mid-epoch),
/// plus headroom for solver-service workers interleaving distinct Φ's
/// on one shared backend. Manifests are runtime data and may carry a
/// larger `k_multi`: that only costs rematerializations (results are
/// unchanged), and [`NativeBackend::from_manifest`] warns about it.
const MAT_CACHE_SLOTS: usize = K_MULTI + 5;

/// Which evaluator runs a loss: the engine (cached materialization +
/// row-block fan-out on an explicit config) or the retained PR-1 scalar
/// reference path.
#[derive(Clone, Copy, Debug)]
enum EvalPath {
    Engine(ParallelConfig),
    Reference,
}

/// One dispatch's [`EvalOptions`] resolved against a preset's defaults:
/// the effective engine config, soft-boundary weight, probe-lane cap
/// and precision tier.
#[derive(Clone, Copy, Debug)]
struct DispatchOpts {
    par: ParallelConfig,
    bw: f32,
    probes: Option<usize>,
    prec: EvalPrecision,
}

impl PresetEval {
    /// Resolve per-dispatch [`EvalOptions`] against this preset's
    /// defaults. Overrides a preset cannot honor (a boundary weight on
    /// a hard-constrained problem, a non-finite/negative weight) are
    /// loud errors — never silently ignored or clamped.
    fn resolve(&self, opts: &EvalOptions) -> Result<DispatchOpts> {
        let par = match opts.parallel {
            Some(p) => {
                // per-job overrides cap at the shared pool's budget now
                // instead of oversubscribing — warn (once) when capped
                super::pool::note_parallel_override(p.threads);
                p
            }
            None => self.par.get(),
        };
        let bw = match opts.bc_weight {
            Some(w) => {
                anyhow::ensure!(
                    w.is_finite() && w >= 0.0,
                    "bc_weight {w} must be a finite non-negative number"
                );
                anyhow::ensure!(
                    self.problem.boundary().is_some(),
                    "problem '{}' has no soft constraints — a boundary-loss \
                     weight override is meaningless",
                    self.problem.name()
                );
                w
            }
            None => self.bc_default(),
        };
        let prec = opts.precision.unwrap_or(EvalPrecision::DEFAULT);
        if let EvalPrecision::Quantized { bits } = prec {
            anyhow::ensure!(
                (2..=24).contains(&bits),
                "quantized precision q{bits} out of range (supported: q2..q24)"
            );
        }
        Ok(DispatchOpts {
            par,
            bw,
            probes: opts.probe_workers,
            prec,
        })
    }

    /// The materialized layer operands for Φ — cached by exact phase
    /// vector ("once per phase-vector, not per call").
    fn materialized(&self, phi: &[f32]) -> Arc<MaterializedNet> {
        let tel = &telemetry::global().engine;
        {
            let mut cache = self.mat_cache.lock().unwrap();
            if let Some(i) = cache.iter().position(|(p, _)| p.as_slice() == phi) {
                let hit = cache.remove(i);
                let m = hit.1.clone();
                cache.insert(0, hit);
                tel.mat_cache_hits.incr();
                return m;
            }
        }
        // build OUTSIDE the lock: materialization is the expensive part
        // and concurrent workers may be evaluating a different Φ
        tel.mat_cache_misses.incr();
        let m = Arc::new(self.net.materialize(phi));
        let mut cache = self.mat_cache.lock().unwrap();
        // two workers can race to build the same Φ; re-check under the
        // second lock so the loser adopts the winner's entry instead of
        // inserting a duplicate (which would waste a MAT_CACHE_SLOT and
        // could evict a live probe entry mid-epoch). The loser still
        // built (and discards) a net, so its miss above stands.
        if let Some(i) = cache.iter().position(|(p, _)| p.as_slice() == phi) {
            let hit = cache.remove(i);
            let m = hit.1.clone();
            cache.insert(0, hit);
            return m;
        }
        cache.insert(0, (phi.to_vec(), m.clone()));
        let evicted = cache.len().saturating_sub(MAT_CACHE_SLOTS);
        if evicted > 0 {
            tel.mat_cache_evictions.add(evicted as u64);
        }
        cache.truncate(MAT_CACHE_SLOTS);
        m
    }

    /// Engine forward: cached materialization + parallel row-blocks on
    /// an explicit engine config (the per-probe budget of a batched
    /// dispatch, or the backend's current setting), in the dispatch's
    /// precision tier. F32 is bit-identical to the PR-1 oracle;
    /// Quantized runs the f32 engine on weights-quantized operands; F64
    /// runs the double-precision oracle forward.
    fn forward_f_with(
        &self,
        phi: &[f32],
        xs: &[f32],
        par: ParallelConfig,
        prec: EvalPrecision,
    ) -> Vec<f32> {
        let mat = self.materialized(phi);
        match prec {
            EvalPrecision::F32 => self.net.forward_f(&mat, xs, par),
            EvalPrecision::F64 => self.net.forward_f64(&mat, xs, par),
            EvalPrecision::Quantized { bits } => {
                self.net.forward_f(&mat.quantized(bits), xs, par)
            }
        }
    }

    /// Transformed solution u(Φ, x) for a flat batch of rows.
    fn forward_u(&self, phi: &[f32], xs: &[f32], par: ParallelConfig, prec: EvalPrecision) -> Vec<f32> {
        let d = self.problem.in_dim();
        let f = self.forward_f_with(phi, xs, par, prec);
        f.iter()
            .enumerate()
            .map(|(i, &fv)| self.problem.transform(fv, &xs[i * d..(i + 1) * d]))
            .collect()
    }

    /// [`Self::forward_u`] through the PR-1 scalar reference path.
    fn forward_u_reference(&self, phi: &[f32], xs: &[f32]) -> Vec<f32> {
        let d = self.problem.in_dim();
        let f = self.net.forward_f_reference(phi, xs);
        f.iter()
            .enumerate()
            .map(|(i, &fv)| self.problem.transform(fv, &xs[i * d..(i + 1) * d]))
            .collect()
    }

    /// Default soft-constraint boundary weight: 0 unless the problem
    /// declares a [`crate::pde::SoftBoundary`] (then the stored default
    /// — problem default → manifest `hyper.bc_weight` → the deprecated
    /// [`Backend::set_bc_weight`] shim).
    fn bc_default(&self) -> f32 {
        if self.problem.boundary().is_some() {
            f32::from_bits(self.bc_weight.load(Ordering::Relaxed))
        } else {
            0.0
        }
    }

    /// Append one boundary projection per collocation point of `xr` to
    /// `x_all` (evaluated in the same dispatch as the stencil/smoothing
    /// rows) and collect the target u values.
    fn append_boundary_rows(&self, xr: &[f32], x_all: &mut Vec<f32>, targets: &mut Vec<f32>) {
        let d = self.problem.in_dim();
        let b = xr.len() / d;
        let mut xb = vec![0.0f32; d];
        for p in 0..b {
            let t = self
                .problem
                .boundary_project(p, &xr[p * d..(p + 1) * d], &mut xb);
            x_all.extend_from_slice(&xb);
            targets.push(t);
        }
    }

    /// Weighted boundary MSE over the projected rows appended by
    /// [`Self::append_boundary_rows`] (`rows0` = index of the first
    /// boundary row in the dispatched batch). The F64 tier reduces in
    /// f64 ([`simd::sum_sq_f64`]); the cheaper tiers keep the
    /// bit-exact sequential f32 accumulation.
    fn boundary_mse(
        &self,
        f: &[f32],
        x_all: &[f32],
        rows0: usize,
        targets: &[f32],
        prec: EvalPrecision,
    ) -> f32 {
        let d = self.problem.in_dim();
        if prec == EvalPrecision::F64 {
            let errs: Vec<f32> = targets
                .iter()
                .enumerate()
                .map(|(p, tgt)| {
                    let row = &x_all[(rows0 + p) * d..(rows0 + p + 1) * d];
                    self.problem.transform(f[rows0 + p], row) - tgt
                })
                .collect();
            return (simd::sum_sq_f64(&errs) / targets.len() as f64) as f32;
        }
        let mut acc = 0.0f32;
        for (p, tgt) in targets.iter().enumerate() {
            let row = &x_all[(rows0 + p) * d..(rows0 + p + 1) * d];
            let u = self.problem.transform(f[rows0 + p], row);
            let e = u - tgt;
            acc += e * e;
        }
        acc / targets.len() as f32
    }

    /// BP-free FD-stencil loss (python `pinn.make_loss_fd`) under one
    /// dispatch's resolved options.
    fn loss_fd(&self, phi: &[f32], xr: &[f32], o: DispatchOpts) -> f32 {
        self.loss_fd_impl(phi, xr, EvalPath::Engine(o.par), o.bw, o.prec)
    }

    /// [`Self::loss_fd`] through the PR-1 scalar reference path (with
    /// the preset's default boundary weight; always the F32 tier — the
    /// reference IS the f32 oracle).
    fn loss_fd_reference(&self, phi: &[f32], xr: &[f32]) -> f32 {
        self.loss_fd_impl(phi, xr, EvalPath::Reference, self.bc_default(), EvalPrecision::F32)
    }

    /// Probe-parallel FD loss over K phase settings (flat (K, d) in
    /// `phis`): the outer level of the engine's two-level parallelism.
    /// Each probe evaluates exactly [`Self::loss_fd`] on its share of
    /// the thread budget, so the output equals K sequential single-Φ
    /// losses bit for bit (for any `o.probes` lane cap).
    fn loss_fd_batch(&self, phis: &[f32], k: usize, xr: &[f32], o: DispatchOpts) -> Vec<f32> {
        let d = phis.len() / k;
        let mut out = vec![0.0f32; k];
        telemetry::global().engine.probe_fanouts.incr();
        telemetry::global().engine.probe_lanes.add(k as u64);
        for_probes_capped(o.par, o.probes, &mut out, |i, inner| {
            self.loss_fd_impl(&phis[i * d..(i + 1) * d], xr, EvalPath::Engine(inner), o.bw, o.prec)
        });
        out
    }

    fn loss_fd_impl(
        &self,
        phi: &[f32],
        xr: &[f32],
        path: EvalPath,
        bw: f32,
        prec: EvalPrecision,
    ) -> f32 {
        let d = self.problem.in_dim();
        let s = self.problem.n_stencil();
        let dim = self.problem.dim();
        let h = self.fd_h;
        let b = xr.len() / d;
        let mut x_all = Vec::with_capacity(b * s * d + if bw > 0.0 { b * d } else { 0 });
        for p in 0..b {
            self.problem
                .stencil_rows(&xr[p * d..(p + 1) * d], h, &mut x_all);
        }
        // soft-constraint problems ride their boundary projections along
        // in the same dispatch (rows b·s ..)
        let mut targets = Vec::new();
        if bw > 0.0 {
            self.append_boundary_rows(xr, &mut x_all, &mut targets);
        }
        let f = match path {
            EvalPath::Reference => self.net.forward_f_reference(phi, &x_all),
            EvalPath::Engine(par) => self.forward_f_with(phi, &x_all, par, prec),
        };
        let need_d2 = self.problem.needs_d2();
        let mut df = vec![0.0f32; d];
        let mut d2 = vec![0.0f32; dim];
        // F64 tier: collect residuals and reduce in f64; cheaper tiers
        // keep the bit-exact sequential f32 accumulation
        let wide = prec == EvalPrecision::F64;
        let mut rs = Vec::with_capacity(if wide { b } else { 0 });
        let mut acc = 0.0f32;
        for p in 0..b {
            let fr = &f[p * s..(p + 1) * s];
            let f0 = fr[0];
            let mut lap_sum = 0.0f32;
            for i in 0..dim {
                let fp = fr[1 + 2 * i];
                let fm = fr[2 + 2 * i];
                df[i] = (fp - fm) / (2.0 * h);
                lap_sum += fp - 2.0 * f0 + fm;
                if need_d2 {
                    d2[i] = (fp - 2.0 * f0 + fm) / (h * h);
                }
            }
            let lap = lap_sum / (h * h);
            if self.problem.has_time() {
                df[dim] = (fr[s - 1] - f0) / h;
            }
            let r = self
                .problem
                .residual(f0, &df, lap, &d2, &xr[p * d..(p + 1) * d]);
            if wide {
                rs.push(r);
            } else {
                acc += r * r;
            }
        }
        let res = if wide {
            (simd::sum_sq_f64(&rs) / b as f64) as f32
        } else {
            acc / b as f32
        };
        if bw > 0.0 {
            res + bw * self.boundary_mse(&f, &x_all, b * s, &targets, prec)
        } else {
            res
        }
    }

    /// Probe-parallel Stein loss over K phase settings — the Stein
    /// counterpart of [`Self::loss_fd_batch`], sharing the smoothing
    /// directions `z` across probes exactly like the sequential
    /// trainer's per-probe `loss_stein` dispatches did.
    fn loss_stein_batch(
        &self,
        phis: &[f32],
        k: usize,
        xr: &[f32],
        z: &[f32],
        o: DispatchOpts,
    ) -> Vec<f32> {
        let d = phis.len() / k;
        let mut out = vec![0.0f32; k];
        telemetry::global().engine.probe_fanouts.incr();
        telemetry::global().engine.probe_lanes.add(k as u64);
        for_probes_capped(o.par, o.probes, &mut out, |i, inner| {
            self.loss_stein(&phis[i * d..(i + 1) * d], xr, z, inner, o.bw, o.prec)
        });
        out
    }

    /// Gaussian-Stein estimator loss (python `pinn.make_loss_stein`).
    fn loss_stein(
        &self,
        phi: &[f32],
        xr: &[f32],
        z: &[f32],
        par: ParallelConfig,
        bw: f32,
        prec: EvalPrecision,
    ) -> f32 {
        let d = self.problem.in_dim();
        let dim = self.problem.dim();
        let q = self.stein_q;
        let sigma = self.stein_sigma;
        let b = xr.len() / d;
        let rows = 2 * q + 1;
        let mut x_all = Vec::with_capacity(b * rows * d + if bw > 0.0 { b * d } else { 0 });
        for p in 0..b {
            let x = &xr[p * d..(p + 1) * d];
            x_all.extend_from_slice(x);
            for k in 0..q {
                for j in 0..d {
                    x_all.push(x[j] + sigma * z[k * d + j]);
                }
            }
            for k in 0..q {
                for j in 0..d {
                    x_all.push(x[j] - sigma * z[k * d + j]);
                }
            }
        }
        let mut targets = Vec::new();
        if bw > 0.0 {
            self.append_boundary_rows(xr, &mut x_all, &mut targets);
        }
        let f = self.forward_f_with(phi, &x_all, par, prec);
        let z_sq: Vec<f32> = (0..q)
            .map(|k| z[k * d..k * d + dim].iter().map(|v| v * v).sum())
            .collect();
        let need_d2 = self.problem.needs_d2();
        let mut df = vec![0.0f32; d];
        let mut d2 = vec![0.0f32; dim];
        let wide = prec == EvalPrecision::F64;
        let mut rs = Vec::with_capacity(if wide { b } else { 0 });
        let mut acc = 0.0f32;
        for p in 0..b {
            let fr = &f[p * rows..(p + 1) * rows];
            let f0 = fr[0];
            // ∇f ≈ E[(f+ − f−)/(2σ) z]
            for j in 0..d {
                let mut sum = 0.0f32;
                for k in 0..q {
                    sum += (fr[1 + k] - fr[1 + q + k]) / (2.0 * sigma) * z[k * d + j];
                }
                df[j] = sum / q as f32;
            }
            // Δ_x f ≈ E[(f+ + f− − 2f0)(‖z_x‖² − D)] / (2σ²)
            let mut lsum = 0.0f32;
            for k in 0..q {
                lsum += (fr[1 + k] + fr[1 + q + k] - 2.0 * f0) * (z_sq[k] - dim as f32);
            }
            let lap = lsum / q as f32 / (2.0 * sigma * sigma);
            // per-dim ∂ⱼⱼf ≈ E[(f+ + f− − 2f0)(zⱼ² − 1)] / (2σ²), only
            // assembled for anisotropic-diffusion problems
            if need_d2 {
                for j in 0..dim {
                    let mut sum = 0.0f32;
                    for k in 0..q {
                        let zj = z[k * d + j];
                        sum += (fr[1 + k] + fr[1 + q + k] - 2.0 * f0) * (zj * zj - 1.0);
                    }
                    d2[j] = sum / q as f32 / (2.0 * sigma * sigma);
                }
            }
            let r = self
                .problem
                .residual(f0, &df, lap, &d2, &xr[p * d..(p + 1) * d]);
            if wide {
                rs.push(r);
            } else {
                acc += r * r;
            }
        }
        let res = if wide {
            (simd::sum_sq_f64(&rs) / b as f64) as f32
        } else {
            acc / b as f32
        };
        if bw > 0.0 {
            res + bw * self.boundary_mse(&f, &x_all, b * rows, &targets, prec)
        } else {
            res
        }
    }

    /// Fused cross-job probe pass: the probes of SEVERAL same-preset
    /// jobs flattened into ONE [`for_probes_capped`] fan-out, so
    /// co-scheduled jobs share the engine's thread budget (and this
    /// preset's Φ-keyed materialization cache) instead of competing for
    /// it. Each flat probe evaluates exactly the per-probe kernel of
    /// the unfused batched dispatch ([`Self::loss_fd_impl`] /
    /// [`Self::loss_stein`]) under its OWN job's resolved boundary
    /// weight, and the engine config is latency-only, so every job's
    /// fused losses equal its isolated `loss_multi` /
    /// `loss_stein_multi` dispatch bit for bit.
    fn loss_fused(&self, jobs: &[FusedLossJob]) -> Result<Vec<Vec<f32>>> {
        let in_dim = self.problem.in_dim();
        // resolve every job's options (and validate its buffers) up
        // front: an unhonorable override fails the whole pass loudly
        // before any probe runs
        let mut resolved = Vec::with_capacity(jobs.len());
        for (ji, j) in jobs.iter().enumerate() {
            anyhow::ensure!(
                j.k > 0 && j.phis.len() % j.k == 0,
                "fused job {ji}: phis length {} is not a (k, d) block for k = {}",
                j.phis.len(),
                j.k
            );
            anyhow::ensure!(
                !j.xr.is_empty() && j.xr.len() % in_dim == 0,
                "fused job {ji}: xr length {} is not a (batch, {in_dim}) block",
                j.xr.len()
            );
            if j.kind == FusedLossKind::Stein {
                let want = self.stein_q * in_dim;
                anyhow::ensure!(
                    j.z.len() == want,
                    "fused job {ji}: z length {} != (stein_q, in_dim) = {want}",
                    j.z.len()
                );
            }
            resolved.push(
                self.resolve(&j.opts)
                    .with_context(|| format!("fused job {ji}"))?,
            );
        }
        // precision changes RESULTS (unlike the latency-only options),
        // so a fused pass must be precision-uniform: mixed gangs are a
        // scheduler bug upstream — fail loudly instead of silently
        // evaluating some jobs in the wrong tier
        if let Some(first) = resolved.first() {
            for (ji, o) in resolved.iter().enumerate() {
                anyhow::ensure!(
                    o.prec == first.prec,
                    "fused job {ji}: precision {} differs from the gang's {} — \
                     mixed-precision jobs must not be fused",
                    o.prec,
                    first.prec
                );
            }
        }
        // each fused job is one per-tier dispatch, same as its unfused
        // `run_with` would have been
        {
            let tel = &telemetry::global().engine;
            for o in &resolved {
                match o.prec {
                    EvalPrecision::F32 => tel.dispatches_f32.incr(),
                    EvalPrecision::F64 => tel.dispatches_f64.incr(),
                    EvalPrecision::Quantized { .. } => tel.dispatches_quantized.incr(),
                }
            }
        }
        // flat (job, probe) index over the union of all jobs' probes
        let mut index = Vec::new();
        for (ji, j) in jobs.iter().enumerate() {
            let d = j.phis.len() / j.k;
            for p in 0..j.k {
                index.push((ji, p, d));
            }
        }
        let mut flat = vec![0.0f32; index.len()];
        telemetry::global().engine.probe_fanouts.incr();
        telemetry::global().engine.probe_lanes.add(flat.len() as u64);
        for_probes_capped(self.par.get(), None, &mut flat, |i, inner| {
            let (ji, p, d) = index[i];
            let j = &jobs[ji];
            let o = &resolved[ji];
            let phi = &j.phis[p * d..(p + 1) * d];
            match j.kind {
                FusedLossKind::Fd => {
                    self.loss_fd_impl(phi, j.xr, EvalPath::Engine(inner), o.bw, o.prec)
                }
                FusedLossKind::Stein => self.loss_stein(phi, j.xr, j.z, inner, o.bw, o.prec),
            }
        });
        // split the flat probe losses back per job
        let mut out = Vec::with_capacity(jobs.len());
        let mut off = 0;
        for j in jobs {
            out.push(flat[off..off + j.k].to_vec());
            off += j.k;
        }
        Ok(out)
    }

    /// Validation MSE vs exact-solution targets (python `make_validate`).
    fn validate(
        &self,
        phi: &[f32],
        xv: &[f32],
        uv: &[f32],
        par: ParallelConfig,
        prec: EvalPrecision,
    ) -> f32 {
        let u = self.forward_u(phi, xv, par, prec);
        if prec == EvalPrecision::F64 {
            let errs: Vec<f32> = u.iter().zip(uv).map(|(a, b)| a - b).collect();
            return (simd::sum_sq_f64(&errs) / uv.len() as f64) as f32;
        }
        let mut acc = 0.0f32;
        for (a, b) in u.iter().zip(uv) {
            let e = a - b;
            acc += e * e;
        }
        acc / uv.len() as f32
    }
}

#[derive(Clone, Copy, Debug)]
enum EntryKind {
    Forward,
    Loss,
    LossMulti,
    LossStein,
    LossSteinMulti,
    Validate,
}

/// A native entry point (the counterpart of a compiled HLO executable).
pub struct NativeEntry {
    meta: EntryMeta,
    kind: EntryKind,
    eval: Arc<PresetEval>,
    dispatches: AtomicU64,
}

impl Entry for NativeEntry {
    fn meta(&self) -> &EntryMeta {
        &self.meta
    }

    fn dispatches(&self) -> u64 {
        self.dispatches.load(Ordering::Relaxed)
    }

    fn run_with(&self, inputs: &[&[f32]], opts: &EvalOptions) -> Result<Vec<Vec<f32>>> {
        self.meta.check_inputs(inputs)?;
        // resolve the dispatch's options against the preset defaults
        // BEFORE touching any state: an unhonorable override (e.g. a
        // boundary weight on a hard-constrained problem) fails loudly
        let o = self
            .eval
            .resolve(opts)
            .with_context(|| format!("entry '{}'", self.meta.name))?;
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        {
            let tel = &telemetry::global().engine;
            match o.prec {
                EvalPrecision::F32 => tel.dispatches_f32.incr(),
                EvalPrecision::F64 => tel.dispatches_f64.incr(),
                EvalPrecision::Quantized { .. } => tel.dispatches_quantized.incr(),
            }
        }
        let out = match self.kind {
            EntryKind::Forward => self.eval.forward_u(inputs[0], inputs[1], o.par, o.prec),
            EntryKind::Loss => vec![self.eval.loss_fd(inputs[0], inputs[1], o)],
            EntryKind::LossMulti => {
                let k = self.meta.inputs[0].1[0]; // phis is (K, d)
                self.eval.loss_fd_batch(inputs[0], k, inputs[1], o)
            }
            EntryKind::LossStein => {
                vec![self.eval.loss_stein(inputs[0], inputs[1], inputs[2], o.par, o.bw, o.prec)]
            }
            EntryKind::LossSteinMulti => {
                let k = self.meta.inputs[0].1[0]; // phis is (K, d)
                self.eval.loss_stein_batch(inputs[0], k, inputs[1], inputs[2], o)
            }
            EntryKind::Validate => {
                vec![self.eval.validate(inputs[0], inputs[1], inputs[2], o.par, o.prec)]
            }
        };
        Ok(vec![out])
    }
}

fn entry_kind(name: &str) -> Result<EntryKind> {
    match name {
        "forward" => Ok(EntryKind::Forward),
        "loss" => Ok(EntryKind::Loss),
        "loss_multi" => Ok(EntryKind::LossMulti),
        "loss_stein" => Ok(EntryKind::LossStein),
        "loss_stein_multi" => Ok(EntryKind::LossSteinMulti),
        "validate" => Ok(EntryKind::Validate),
        "grad" => Err(anyhow!(
            "entry 'grad' needs the pjrt backend (exact-BP autodiff is not \
             implemented natively; build with --features pjrt + artifacts)"
        )),
        other => Err(anyhow!("unknown entry '{other}'")),
    }
}

/// The pure-rust execution backend. `Send + Sync`: share one instance
/// across threads instead of one PJRT client per worker.
pub struct NativeBackend {
    manifest: Manifest,
    evals: HashMap<String, Arc<PresetEval>>,
    cache: Mutex<HashMap<(String, String), Arc<NativeEntry>>>,
    /// engine parallelism, shared with every evaluator (runtime-tunable
    /// through [`Backend::set_parallel`])
    par: Arc<ParallelCtl>,
}

impl NativeBackend {
    /// Build evaluators for every preset of a parsed manifest. The
    /// parameter layout is re-derived from each arch block and checked
    /// against the manifest's `param_dim` (catching drift between the
    /// python lowering and this evaluator).
    pub fn from_manifest(manifest: Manifest) -> Result<NativeBackend> {
        if manifest.k_multi > MAT_CACHE_SLOTS {
            crate::warn_!(
                "manifest k_multi {} exceeds the {}-slot per-preset \
                 materialization cache: probe-parallel training dispatches \
                 will rematerialize mid-epoch (latency only — results are \
                 unchanged)",
                manifest.k_multi,
                MAT_CACHE_SLOTS
            );
        }
        let par = Arc::new(ParallelCtl::new(ParallelConfig::auto()));
        let mut evals = HashMap::new();
        for (name, pm) in &manifest.presets {
            let (net, layout) = build_net(&pm.arch)
                .with_context(|| format!("building native evaluator for preset '{name}'"))?;
            anyhow::ensure!(
                layout.param_dim == pm.layout.param_dim,
                "preset '{}': arch implies {} params but manifest says {}",
                name,
                layout.param_dim,
                pm.layout.param_dim
            );
            anyhow::ensure!(
                net.in_dim == pm.pde.in_dim(),
                "preset '{}': arch in_dim {} != pde in_dim {}",
                name,
                net.in_dim,
                pm.pde.in_dim()
            );
            // shape contracts the evaluator indexes by (panic-free later):
            // loss_multi phis is (k_multi, d); loss_stein z is (stein_q, in)
            if let Some(em) = pm.entries.get("loss_multi") {
                let want = vec![manifest.k_multi, pm.layout.param_dim];
                let got = em.inputs.first().map(|(_, s)| s.clone()).unwrap_or_default();
                anyhow::ensure!(
                    got == want,
                    "preset '{name}': loss_multi phis shape {got:?} != (k_multi, d) {want:?}"
                );
            }
            if let Some(em) = pm.entries.get("loss_stein") {
                let want = vec![pm.hyper.stein_q, pm.pde.in_dim()];
                let got = em.inputs.get(2).map(|(_, s)| s.clone()).unwrap_or_default();
                anyhow::ensure!(
                    got == want,
                    "preset '{name}': loss_stein z shape {got:?} != (stein_q, in_dim) {want:?}"
                );
            }
            if let Some(em) = pm.entries.get("loss_stein_multi") {
                let want = vec![manifest.k_multi, pm.layout.param_dim];
                let got = em.inputs.first().map(|(_, s)| s.clone()).unwrap_or_default();
                anyhow::ensure!(
                    got == want,
                    "preset '{name}': loss_stein_multi phis shape {got:?} != (k_multi, d) {want:?}"
                );
                let want_z = vec![pm.hyper.stein_q, pm.pde.in_dim()];
                let got_z = em.inputs.get(2).map(|(_, s)| s.clone()).unwrap_or_default();
                anyhow::ensure!(
                    got_z == want_z,
                    "preset '{name}': loss_stein_multi z shape {got_z:?} != (stein_q, in_dim) {want_z:?}"
                );
            }
            // soft-constraint weight: manifest hyper override, else the
            // problem's own default; 0 for hard-constrained problems
            let bc_default = pm.pde.boundary().map(|sb| sb.default_weight).unwrap_or(0.0);
            let bc = pm.hyper.bc_weight.map(|w| w as f32).unwrap_or(bc_default);
            anyhow::ensure!(
                bc >= 0.0 && bc.is_finite(),
                "preset '{name}': bc_weight {bc} must be a finite non-negative number"
            );
            evals.insert(
                name.clone(),
                Arc::new(PresetEval {
                    problem: pm.pde.clone(),
                    net,
                    fd_h: pm.hyper.fd_h as f32,
                    stein_sigma: pm.hyper.stein_sigma as f32,
                    stein_q: pm.hyper.stein_q,
                    bc_weight: AtomicU32::new(bc.to_bits()),
                    par: par.clone(),
                    mat_cache: Mutex::new(Vec::new()),
                }),
            );
        }
        Ok(NativeBackend {
            manifest,
            evals,
            cache: Mutex::new(HashMap::new()),
            par,
        })
    }

    /// Load from a `manifest.json` directory (artifact files not needed).
    pub fn load(artifacts_dir: &Path) -> Result<NativeBackend> {
        let manifest = Manifest::load(artifacts_dir).with_context(|| {
            format!("loading manifest from {}", artifacts_dir.display())
        })?;
        NativeBackend::from_manifest(manifest)
    }

    /// The in-repo preset registry (no files needed at all).
    pub fn builtin() -> NativeBackend {
        NativeBackend::from_manifest(builtin_manifest())
            .expect("builtin manifest is well-formed") // lint: allow(unwrap): compile-time constant, exercised by every test
    }

    /// `load` when a manifest exists at `dir`, else [`Self::builtin`].
    pub fn load_or_builtin(dir: &Path) -> Result<NativeBackend> {
        if dir.join("manifest.json").exists() {
            NativeBackend::load(dir)
        } else {
            Ok(NativeBackend::builtin())
        }
    }

    fn eval(&self, preset: &str) -> Result<&Arc<PresetEval>> {
        self.evals
            .get(preset)
            .ok_or_else(|| anyhow!("no evaluator for preset '{preset}'"))
    }

    /// The `forward` entry through the retained PR-1 scalar reference
    /// path (per-call materialization, whole-batch matmul, one thread).
    /// Correctness oracle for the engine and the baseline its recorded
    /// speedups are measured against (`benches/latency.rs`).
    pub fn forward_reference(&self, preset: &str, phi: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        Ok(self.eval(preset)?.forward_u_reference(phi, x))
    }

    /// The `loss` (FD-stencil) entry through the PR-1 scalar reference
    /// path — see [`Self::forward_reference`].
    pub fn loss_reference(&self, preset: &str, phi: &[f32], xr: &[f32]) -> Result<f32> {
        Ok(self.eval(preset)?.loss_fd_reference(phi, xr))
    }
}

impl Backend for NativeBackend {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn platform(&self) -> String {
        "native-cpu".to_string()
    }

    fn parallel(&self) -> ParallelConfig {
        self.par.get()
    }

    fn set_parallel(&self, cfg: ParallelConfig) -> bool {
        self.par.set(cfg);
        // one global thread budget: the backend-wide engine default also
        // sizes the shared worker pool all dispatches fan out on
        super::pool::set_budget(cfg.threads);
        true
    }

    fn set_bc_weight(&self, preset: &str, weight: f32) -> bool {
        // reject (don't clamp) invalid weights: a negative weight would
        // silently disable the soft-constraint term
        if weight.is_nan() || weight < 0.0 {
            return false;
        }
        match self.evals.get(preset) {
            Some(eval) if eval.problem.boundary().is_some() => {
                eval.bc_weight.store(weight.to_bits(), Ordering::Relaxed);
                true
            }
            _ => false,
        }
    }

    fn entry(&self, preset: &str, entry: &str) -> Result<Arc<dyn Entry>> {
        let key = (preset.to_string(), entry.to_string());
        if let Some(e) = self.cache.lock().unwrap().get(&key) {
            return Ok(e.clone());
        }
        let pm = self.manifest.preset(preset)?;
        let em = match pm.entries.get(entry) {
            Some(em) => em.clone(),
            None => {
                // distinguish "a known kind this backend cannot run"
                // (grad -> curated pjrt pointer) from a plain miss
                entry_kind(entry).with_context(|| format!("preset '{preset}'"))?;
                anyhow::bail!("preset '{preset}' has no entry '{entry}'");
            }
        };
        let kind = entry_kind(entry)
            .with_context(|| format!("preset '{preset}', entry '{entry}'"))?;
        let eval = self
            .evals
            .get(preset)
            .ok_or_else(|| anyhow!("no evaluator for preset '{preset}'"))?
            .clone();
        let wrapped = Arc::new(NativeEntry {
            meta: em,
            kind,
            eval,
            dispatches: AtomicU64::new(0),
        });
        self.cache.lock().unwrap().insert(key, wrapped.clone());
        Ok(wrapped)
    }

    fn loss_fused(&self, preset: &str, jobs: &[FusedLossJob]) -> Result<Vec<Vec<f32>>> {
        self.eval(preset)?.loss_fused(jobs)
    }
}

// ---------------------------------------------------------------------------
// Built-in preset registry (mirrors python/compile/model.py PRESETS, plus
// micro presets sized for fast default-build tests).
// ---------------------------------------------------------------------------

struct BuiltinPreset {
    name: &'static str,
    /// problem name, resolved against [`crate::pde::registry`]
    pde: &'static str,
    /// (factors_m, factors_n, ranks) for tonn; hidden for onn
    tonn: Option<(&'static [usize], &'static [usize], &'static [usize])>,
    hidden: usize,
    entries: &'static [&'static str],
}

const ALL_ENTRIES: &[&str] = &[
    "forward",
    "loss",
    "loss_multi",
    "loss_stein",
    "loss_stein_multi",
    "validate",
];

const BUILTIN_PRESETS: &[BuiltinPreset] = &[
    // -- default reproduction scale (Table-1 runs) -----------------------
    BuiltinPreset {
        name: "tonn_small",
        pde: "hjb20",
        tonn: Some((&[4, 4, 4], &[4, 4, 4], &[1, 2, 2, 1])),
        hidden: 64,
        entries: ALL_ENTRIES,
    },
    BuiltinPreset {
        name: "onn_small",
        pde: "hjb20",
        tonn: None,
        hidden: 64,
        entries: &["forward", "loss", "loss_multi", "validate"],
    },
    // -- paper scale (n=1024; Table-2 census) ----------------------------
    BuiltinPreset {
        name: "tonn_paper",
        pde: "hjb20",
        tonn: Some((&[4, 8, 4, 8], &[8, 4, 8, 4], &[1, 2, 1, 2, 1])),
        hidden: 1024,
        entries: &["forward", "loss", "loss_multi", "validate"],
    },
    BuiltinPreset {
        name: "onn_paper",
        pde: "hjb20",
        tonn: None,
        hidden: 1024,
        entries: &["forward", "validate"],
    },
    // -- TT-rank ablation (A3) -------------------------------------------
    BuiltinPreset {
        name: "tonn_rank1",
        pde: "hjb20",
        tonn: Some((&[4, 4, 4], &[4, 4, 4], &[1, 1, 1, 1])),
        hidden: 64,
        entries: &["forward", "loss", "loss_multi", "validate"],
    },
    BuiltinPreset {
        name: "tonn_rank4",
        pde: "hjb20",
        tonn: Some((&[4, 4, 4], &[4, 4, 4], &[1, 4, 4, 1])),
        hidden: 64,
        entries: &["forward", "loss", "loss_multi", "validate"],
    },
    // -- extension problems ----------------------------------------------
    BuiltinPreset {
        name: "tonn_poisson",
        pde: "poisson2",
        tonn: Some((&[4, 4, 4], &[4, 4, 4], &[1, 2, 2, 1])),
        hidden: 64,
        entries: &["forward", "loss", "loss_multi", "validate"],
    },
    BuiltinPreset {
        name: "tonn_heat",
        pde: "heat2",
        tonn: Some((&[4, 4, 4], &[4, 4, 4], &[1, 2, 2, 1])),
        hidden: 64,
        entries: &["forward", "loss", "loss_multi", "validate"],
    },
    // -- micro presets (native-only; sized for fast CI tests) ------------
    BuiltinPreset {
        name: "tonn_micro",
        pde: "poisson2",
        tonn: Some((&[2, 2], &[2, 2], &[1, 2, 1])),
        hidden: 4,
        entries: ALL_ENTRIES,
    },
    BuiltinPreset {
        name: "tonn_micro_heat",
        pde: "heat2",
        tonn: Some((&[2, 2], &[2, 2], &[1, 2, 1])),
        hidden: 4,
        entries: &["forward", "loss", "loss_multi", "validate"],
    },
    // -- scenario presets: one fast-CI-sized preset per registered
    //    problem of the pde registry (hidden >= in_dim; even TT meshes) --
    BuiltinPreset {
        name: "tonn_micro_hjb5",
        pde: "hjb5",
        tonn: Some((&[2, 4], &[4, 2], &[1, 2, 1])),
        hidden: 8,
        entries: ALL_ENTRIES,
    },
    BuiltinPreset {
        name: "tonn_micro_hjb10",
        pde: "hjb10",
        tonn: Some((&[4, 4], &[4, 4], &[1, 2, 1])),
        hidden: 16,
        entries: ALL_ENTRIES,
    },
    BuiltinPreset {
        name: "tonn_hjb50",
        pde: "hjb50",
        tonn: Some((&[4, 4, 4], &[4, 4, 4], &[1, 2, 2, 1])),
        hidden: 64,
        entries: ALL_ENTRIES,
    },
    BuiltinPreset {
        name: "tonn_micro_bs5",
        pde: "bs_basket5",
        tonn: Some((&[2, 4], &[4, 2], &[1, 2, 1])),
        hidden: 8,
        entries: ALL_ENTRIES,
    },
    BuiltinPreset {
        name: "tonn_micro_ac",
        pde: "allen_cahn2",
        tonn: Some((&[2, 2], &[2, 2], &[1, 2, 1])),
        hidden: 4,
        entries: ALL_ENTRIES,
    },
];

fn arr_usize(xs: &[usize]) -> Value {
    Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
}

fn builtin_arch(p: &BuiltinPreset, in_dim: usize) -> Value {
    match p.tonn {
        Some((fm, fn_, ranks)) => Value::obj(vec![
            ("type", Value::Str("tonn".into())),
            ("in_dim", Value::Num(in_dim as f64)),
            ("hidden", Value::Num(p.hidden as f64)),
            ("omega0", Value::Num(6.0)),
            ("factors_m", arr_usize(fm)),
            ("factors_n", arr_usize(fn_)),
            ("ranks", arr_usize(ranks)),
        ]),
        None => Value::obj(vec![
            ("type", Value::Str("onn".into())),
            ("in_dim", Value::Num(in_dim as f64)),
            ("hidden", Value::Num(p.hidden as f64)),
            ("omega0", Value::Num(6.0)),
        ]),
    }
}

fn builtin_hyper() -> Hyper {
    Hyper {
        fd_h: 0.05,
        spsa_mu: 0.02,
        spsa_n: 10,
        lr: 0.02,
        lr_decay: 0.3,
        lr_decay_every: 600,
        epochs: 1500,
        batch: B_RES,
        k_multi: K_MULTI,
        stein_sigma: 0.05,
        stein_q: 20,
        // None = the problem's own SoftBoundary default applies
        bc_weight: None,
        // None = trainer defaults (zo-signsgd / spsa)
        optimizer: None,
        estimator: None,
    }
}

fn builtin_entry_meta(ename: &str, d: usize, ind: usize, stein_q: usize) -> EntryMeta {
    let (inputs, outputs): (Vec<(String, Vec<usize>)>, Vec<Vec<usize>>) = match ename {
        "forward" => (
            vec![("phi".into(), vec![d]), ("x".into(), vec![B_FWD, ind])],
            vec![vec![B_FWD]],
        ),
        "loss" => (
            vec![("phi".into(), vec![d]), ("xr".into(), vec![B_RES, ind])],
            vec![vec![]],
        ),
        "loss_multi" => (
            vec![
                ("phis".into(), vec![K_MULTI, d]),
                ("xr".into(), vec![B_RES, ind]),
            ],
            vec![vec![K_MULTI]],
        ),
        "loss_stein" => (
            vec![
                ("phi".into(), vec![d]),
                ("xr".into(), vec![B_RES, ind]),
                ("z".into(), vec![stein_q, ind]),
            ],
            vec![vec![]],
        ),
        "loss_stein_multi" => (
            vec![
                ("phis".into(), vec![K_MULTI, d]),
                ("xr".into(), vec![B_RES, ind]),
                ("z".into(), vec![stein_q, ind]),
            ],
            vec![vec![K_MULTI]],
        ),
        "validate" => (
            vec![
                ("phi".into(), vec![d]),
                ("xv".into(), vec![B_VAL, ind]),
                ("uv".into(), vec![B_VAL]),
            ],
            vec![vec![]],
        ),
        other => unreachable!("builtin entry {other}"),
    };
    EntryMeta {
        name: ename.to_string(),
        file: String::new(),
        inputs,
        outputs,
    }
}

/// Synthesize the in-repo manifest (the native replacement for the AOT
/// build step's `manifest.json`).
pub fn builtin_manifest() -> Manifest {
    let mut presets = HashMap::new();
    for p in BUILTIN_PRESETS {
        // lint: allow(unwrap): BUILTIN_PRESETS only references registered problems
        let problem = crate::pde::lookup(p.pde).expect("builtin preset names a registered problem");
        let arch = builtin_arch(p, problem.in_dim());
        // lint: allow(unwrap): builtin arch dims are compile-time constants
        let (_, layout) = build_net(&arch).expect("builtin arch is well-formed");
        let hyper = builtin_hyper();
        let d = layout.param_dim;
        let mut entries = HashMap::new();
        for ename in p.entries {
            entries.insert(
                ename.to_string(),
                builtin_entry_meta(ename, d, problem.in_dim(), hyper.stein_q),
            );
        }
        presets.insert(
            p.name.to_string(),
            PresetMeta {
                name: p.name.to_string(),
                pde: problem,
                layout,
                hyper,
                entries,
                arch,
            },
        );
    }
    Manifest {
        dir: PathBuf::from("<builtin>"),
        presets,
        k_multi: K_MULTI,
        b_forward: B_FWD,
        b_residual: B_RES,
        b_validate: B_VAL,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn builtin_layout_census() {
        let be = NativeBackend::builtin();
        let m = be.manifest();
        // tonn_small: 2 x (38 + 64 + 38 + 64 bias) + 64 readout + 1 = 473
        assert_eq!(m.preset("tonn_small").unwrap().layout.param_dim, 473);
        // onn_small: 2 x (2016 + 64 + 2016 + 64 bias) + 64 + 1 = 8385
        assert_eq!(m.preset("onn_small").unwrap().layout.param_dim, 8385);
        assert_eq!(m.k_multi, 11);
        for (name, pm) in &m.presets {
            assert!(pm.layout.param_dim > 0, "{name}");
            assert_eq!(
                pm.entries["forward"].inputs[0].1,
                vec![pm.layout.param_dim],
                "{name}"
            );
        }
    }

    #[test]
    fn micro_forward_and_losses_run() {
        let be = NativeBackend::builtin();
        let pm = be.manifest().preset("tonn_micro").unwrap();
        let mut rng = Rng::new(3);
        let phi = pm.layout.init_vector(&mut rng);
        let fwd = be.entry("tonn_micro", "forward").unwrap();
        let mut x = vec![0.0f32; fwd.meta().input_len(1)];
        rng.fill_uniform(&mut x, 0.0, 1.0);
        let u = fwd.run1(&[&phi, &x]).unwrap();
        assert_eq!(u.len(), B_FWD);
        assert!(u.iter().all(|v| v.is_finite()));
        // boundary points map to exactly 0 (hard Dirichlet transform)
        let mut xb = x.clone();
        xb[0] = 0.0;
        let ub = fwd.run1(&[&phi, &xb]).unwrap();
        assert_eq!(ub[0], 0.0);

        let loss = be.entry("tonn_micro", "loss").unwrap();
        let mut xr = vec![0.0f32; loss.meta().input_len(1)];
        rng.fill_uniform(&mut xr, 0.05, 0.95);
        let l = loss.run_scalar(&[&phi, &xr]).unwrap();
        assert!(l.is_finite() && l >= 0.0);

        // loss_multi row 0 with phi tiled == loss
        let lm = be.entry("tonn_micro", "loss_multi").unwrap();
        let phis: Vec<f32> = (0..K_MULTI).flat_map(|_| phi.iter().copied()).collect();
        let ls = lm.run1(&[&phis, &xr]).unwrap();
        assert_eq!(ls.len(), K_MULTI);
        for v in &ls {
            assert!((v - l).abs() < 1e-6, "{v} vs {l}");
        }
    }

    #[test]
    fn entry_errors_are_loud() {
        let be = NativeBackend::builtin();
        assert!(be.entry("tonn_micro", "backprop").is_err());
        assert!(be.entry("no_such_preset", "forward").is_err());
        let err = format!(
            "{:#}",
            be.entry("tonn_micro", "grad").unwrap_err()
        );
        assert!(err.contains("grad"), "{err}");
        // wrong input length
        let fwd = be.entry("tonn_micro", "forward").unwrap();
        let short = vec![0.0f32; 3];
        let x = vec![0.0f32; fwd.meta().input_len(1)];
        let err = fwd.run(&[&short, &x]).unwrap_err().to_string();
        assert!(err.contains("expects"), "{err}");
        let err2 = fwd.run(&[&x]).unwrap_err().to_string();
        assert!(err2.contains("inputs"), "{err2}");
    }

    #[test]
    fn forward_is_deterministic_and_phi_sensitive() {
        let be = NativeBackend::builtin();
        let pm = be.manifest().preset("tonn_micro").unwrap();
        let fwd = be.entry("tonn_micro", "forward").unwrap();
        let mut rng = Rng::new(5);
        let phi = pm.layout.init_vector(&mut rng);
        let mut x = vec![0.0f32; fwd.meta().input_len(1)];
        rng.fill_uniform(&mut x, 0.1, 0.9);
        let u1 = fwd.run1(&[&phi, &x]).unwrap();
        let u2 = fwd.run1(&[&phi, &x]).unwrap();
        assert_eq!(u1, u2);
        let mut phi2 = phi.clone();
        phi2[0] += 0.3;
        let u3 = fwd.run1(&[&phi2, &x]).unwrap();
        assert_ne!(u1, u3);
        assert_eq!(fwd.dispatches(), 3);
    }

    /// The engine (materialized layers + blocked GEMM + threads) must
    /// reproduce the PR-1 scalar reference path exactly, for every
    /// thread count and block size — the correctness gate that lets the
    /// golden fixtures run against the parallel path unchanged.
    #[test]
    fn engine_matches_reference_for_every_parallel_config() {
        let be = NativeBackend::builtin();
        for preset in ["tonn_micro", "tonn_small", "onn_small"] {
            let pm = be.manifest().preset(preset).unwrap();
            let mut rng = Rng::new(17);
            let phi = pm.layout.init_vector(&mut rng);
            let fwd = be.entry(preset, "forward").unwrap();
            let mut x = vec![0.0f32; fwd.meta().input_len(1)];
            rng.fill_uniform(&mut x, 0.0, 1.0);
            let loss = be.entry(preset, "loss").unwrap();
            let mut xr = vec![0.0f32; loss.meta().input_len(1)];
            rng.fill_uniform(&mut xr, 0.05, 0.95);

            let u_ref = be.forward_reference(preset, &phi, &x).unwrap();
            let l_ref = be.loss_reference(preset, &phi, &xr).unwrap();
            assert!(l_ref.is_finite());

            for cfg in [
                ParallelConfig {
                    threads: 1,
                    block_rows: 64,
                },
                ParallelConfig {
                    threads: 2,
                    block_rows: 7,
                },
                ParallelConfig {
                    threads: 8,
                    block_rows: 3,
                },
            ] {
                assert!(be.set_parallel(cfg));
                let u = fwd.run1(&[&phi, &x]).unwrap();
                assert_eq!(u, u_ref, "{preset}: forward drifted under {cfg:?}");
                let l = loss.run_scalar(&[&phi, &xr]).unwrap();
                assert_eq!(l, l_ref, "{preset}: loss drifted under {cfg:?}");
            }
        }
    }

    /// The probe-parallel batched entries must reproduce per-probe
    /// single-Φ dispatches bit for bit, for any engine config — the
    /// correctness contract that lets the trainer fan an SPSA epoch out
    /// across probes without touching the golden fixtures.
    #[test]
    fn batched_losses_match_per_probe_bitwise() {
        let be = NativeBackend::builtin();
        let pm = be.manifest().preset("tonn_micro").unwrap();
        let d = pm.layout.param_dim;
        let k = be.manifest().k_multi;
        let mut rng = Rng::new(77);
        let phi = pm.layout.init_vector(&mut rng);
        let phis: Vec<f32> = (0..k)
            .flat_map(|ki| phi.iter().map(move |p| p + 0.01 * ki as f32))
            .collect();
        let loss = be.entry("tonn_micro", "loss").unwrap();
        let mut xr = vec![0.0f32; loss.meta().input_len(1)];
        rng.fill_uniform(&mut xr, 0.05, 0.95);
        let stein = be.entry("tonn_micro", "loss_stein").unwrap();
        let mut z = vec![0.0f32; stein.meta().input_len(2)];
        rng.fill_normal(&mut z);

        // sequential per-probe oracle
        assert!(be.set_parallel(ParallelConfig::sequential()));
        let fd_seq: Vec<f32> = (0..k)
            .map(|i| loss.run_scalar(&[&phis[i * d..(i + 1) * d], &xr]).unwrap())
            .collect();
        let st_seq: Vec<f32> = (0..k)
            .map(|i| stein.run_scalar(&[&phis[i * d..(i + 1) * d], &xr, &z]).unwrap())
            .collect();

        let lm = be.entry("tonn_micro", "loss_multi").unwrap();
        let sm = be.entry("tonn_micro", "loss_stein_multi").unwrap();
        for cfg in [
            ParallelConfig { threads: 1, block_rows: 32 },
            ParallelConfig { threads: 3, block_rows: 7 },
            ParallelConfig { threads: 16, block_rows: 4 },
        ] {
            assert!(be.set_parallel(cfg));
            let fd = lm.run1(&[&phis, &xr]).unwrap();
            assert_eq!(fd, fd_seq, "loss_multi drifted under {cfg:?}");
            let st = sm.run1(&[&phis, &xr, &z]).unwrap();
            assert_eq!(st, st_seq, "loss_stein_multi drifted under {cfg:?}");
        }
    }

    /// The per-Φ materialization cache must never leak results across
    /// different phase vectors.
    #[test]
    fn materialization_cache_is_phi_keyed() {
        let be = NativeBackend::builtin();
        let pm = be.manifest().preset("tonn_micro").unwrap();
        let fwd = be.entry("tonn_micro", "forward").unwrap();
        let mut rng = Rng::new(23);
        let phi_a = pm.layout.init_vector(&mut rng);
        let mut phi_b = phi_a.clone();
        phi_b[1] += 0.25;
        let mut x = vec![0.0f32; fwd.meta().input_len(1)];
        rng.fill_uniform(&mut x, 0.1, 0.9);
        let ua1 = fwd.run1(&[&phi_a, &x]).unwrap();
        let ub = fwd.run1(&[&phi_b, &x]).unwrap();
        // back to phi_a: must rebuild (or re-hit) the right operands
        let ua2 = fwd.run1(&[&phi_a, &x]).unwrap();
        assert_eq!(ua1, ua2);
        assert_ne!(ua1, ub);
    }

    /// Every scenario preset (one per registered problem) must evaluate
    /// end-to-end: forward respects the constraint style, and all loss
    /// entries stay finite.
    #[test]
    fn scenario_presets_evaluate() {
        let be = NativeBackend::builtin();
        for preset in [
            "tonn_micro_hjb5",
            "tonn_micro_hjb10",
            "tonn_hjb50",
            "tonn_micro_bs5",
            "tonn_micro_ac",
        ] {
            let pm = be.manifest().preset(preset).unwrap();
            let mut rng = Rng::new(41);
            let phi = pm.layout.init_vector(&mut rng);
            let fwd = be.entry(preset, "forward").unwrap();
            let mut x = vec![0.0f32; fwd.meta().input_len(1)];
            rng.fill_uniform(&mut x, 0.05, 0.95);
            let u = fwd.run1(&[&phi, &x]).unwrap();
            assert!(u.iter().all(|v| v.is_finite()), "{preset}");

            let loss = be.entry(preset, "loss").unwrap();
            let mut xr = vec![0.0f32; loss.meta().input_len(1)];
            rng.fill_uniform(&mut xr, 0.05, 0.95);
            let l = loss.run_scalar(&[&phi, &xr]).unwrap();
            assert!(l.is_finite() && l >= 0.0, "{preset}: loss {l}");

            let stein = be.entry(preset, "loss_stein").unwrap();
            let mut z = vec![0.0f32; stein.meta().input_len(2)];
            rng.fill_normal(&mut z);
            let ls = stein.run_scalar(&[&phi, &xr, &z]).unwrap();
            assert!(ls.is_finite() && ls >= 0.0, "{preset}: stein {ls}");
        }
    }

    /// Hard terminal conditions of the scenario presets hold exactly
    /// after the transform, for any network output.
    #[test]
    fn scenario_hard_constraints_hold() {
        let be = NativeBackend::builtin();
        let pm = be.manifest().preset("tonn_micro_bs5").unwrap();
        let mut rng = Rng::new(5);
        let phi = pm.layout.init_vector(&mut rng);
        let fwd = be.entry("tonn_micro_bs5", "forward").unwrap();
        let mut x = vec![0.0f32; fwd.meta().input_len(1)];
        rng.fill_uniform(&mut x, 0.1, 0.9);
        // pin row 0 to the terminal slice t = 1: u must equal the payoff
        x[5] = 1.0;
        let u = fwd.run1(&[&phi, &x]).unwrap();
        let payoff = pm.pde.exact(&x[..6]);
        assert!(
            (u[0] - payoff).abs() < 1e-5,
            "terminal condition broken: {} vs {payoff}",
            u[0]
        );
    }

    /// The soft-constraint boundary term must be active for the
    /// Allen–Cahn preset, scale with the weight, and be runtime-tunable
    /// through `Backend::set_bc_weight`; presets with hard constraints
    /// must refuse the override.
    #[test]
    fn soft_boundary_term_is_active_and_tunable() {
        let be = NativeBackend::builtin();
        let pm = be.manifest().preset("tonn_micro_ac").unwrap();
        assert!(pm.pde.boundary().is_some());
        let loss = be.entry("tonn_micro_ac", "loss").unwrap();
        let mut rng = Rng::new(9);
        let phi = pm.layout.init_vector(&mut rng);
        let mut xr = vec![0.0f32; loss.meta().input_len(1)];
        rng.fill_uniform(&mut xr, 0.1, 0.9);

        let l_default = loss.run_scalar(&[&phi, &xr]).unwrap();
        assert!(be.set_bc_weight("tonn_micro_ac", 0.0));
        let l_residual_only = loss.run_scalar(&[&phi, &xr]).unwrap();
        assert!(be.set_bc_weight("tonn_micro_ac", 5.0));
        let l_heavy = loss.run_scalar(&[&phi, &xr]).unwrap();
        // default weight is 1.0 > 0: a random-init network violates the
        // BC, so the ladder must be strictly ordered
        assert!(
            l_residual_only < l_default && l_default < l_heavy,
            "boundary term inert: {l_residual_only} / {l_default} / {l_heavy}"
        );
        // same ladder through the Stein estimator
        let stein = be.entry("tonn_micro_ac", "loss_stein").unwrap();
        let mut z = vec![0.0f32; stein.meta().input_len(2)];
        rng.fill_normal(&mut z);
        let s_heavy = stein.run_scalar(&[&phi, &xr, &z]).unwrap();
        assert!(be.set_bc_weight("tonn_micro_ac", 0.0));
        let s_none = stein.run_scalar(&[&phi, &xr, &z]).unwrap();
        assert!(s_none < s_heavy, "stein boundary term inert: {s_none} vs {s_heavy}");

        // hard-constrained presets reject the override, and invalid
        // weights are rejected rather than clamped
        assert!(!be.set_bc_weight("tonn_micro", 1.0));
        assert!(!be.set_bc_weight("no_such_preset", 1.0));
        assert!(!be.set_bc_weight("tonn_micro_ac", -1.0));
        assert!(!be.set_bc_weight("tonn_micro_ac", f32::NAN));
    }

    /// Per-dispatch [`EvalOptions`] must (a) reproduce the old global
    /// `set_bc_weight` mutation bit for bit, (b) never touch the stored
    /// preset default, (c) be latency-only for engine fields, and (d)
    /// reject unhonorable overrides loudly.
    #[test]
    fn per_dispatch_options_override_without_mutating_defaults() {
        let be = NativeBackend::builtin();
        let pm = be.manifest().preset("tonn_micro_ac").unwrap();
        let loss = be.entry("tonn_micro_ac", "loss").unwrap();
        let mut rng = Rng::new(31);
        let phi = pm.layout.init_vector(&mut rng);
        let mut xr = vec![0.0f32; loss.meta().input_len(1)];
        rng.fill_uniform(&mut xr, 0.1, 0.9);

        let l_default = loss.run_scalar(&[&phi, &xr]).unwrap();
        // per-dispatch override == the old global mutation, bit for bit
        let l_opts = loss
            .run_scalar_with(&[&phi, &xr], &EvalOptions::NONE.with_bc_weight(5.0))
            .unwrap();
        assert!(be.set_bc_weight("tonn_micro_ac", 5.0));
        let l_global = loss.run_scalar(&[&phi, &xr]).unwrap();
        assert_eq!(l_opts, l_global, "per-dispatch weight drifted from the shim");
        assert!(be.set_bc_weight("tonn_micro_ac", 1.0)); // restore default
        // ... and the override never touched the stored default
        assert_eq!(loss.run_scalar(&[&phi, &xr]).unwrap(), l_default);

        // engine options ride per dispatch and never change bits
        for threads in [1usize, 3, 8] {
            let o = EvalOptions::NONE.with_parallel(ParallelConfig {
                threads,
                block_rows: 5,
            });
            assert_eq!(
                loss.run_scalar_with(&[&phi, &xr], &o).unwrap(),
                l_default,
                "threads={threads}"
            );
        }

        // invalid / meaningless overrides fail loudly
        let neg = EvalOptions::NONE.with_bc_weight(-1.0);
        assert!(loss.run_scalar_with(&[&phi, &xr], &neg).is_err());
        let nan = EvalOptions::NONE.with_bc_weight(f32::NAN);
        assert!(loss.run_scalar_with(&[&phi, &xr], &nan).is_err());
        let hard = be.entry("tonn_micro", "loss").unwrap();
        let pm_h = be.manifest().preset("tonn_micro").unwrap();
        let mut rng_h = Rng::new(32);
        let phi_h = pm_h.layout.init_vector(&mut rng_h);
        let mut xr_h = vec![0.0f32; hard.meta().input_len(1)];
        rng_h.fill_uniform(&mut xr_h, 0.1, 0.9);
        let err = hard
            .run_scalar_with(&[&phi_h, &xr_h], &EvalOptions::NONE.with_bc_weight(1.0))
            .unwrap_err();
        assert!(format!("{err:#}").contains("soft"), "{err:#}");
    }

    /// The probe-lane cap of a batched dispatch is latency-only: any
    /// `probe_workers` value reproduces the uncapped output bit for bit.
    #[test]
    fn batched_loss_probe_cap_is_latency_only() {
        let be = NativeBackend::builtin();
        let pm = be.manifest().preset("tonn_micro").unwrap();
        let k = be.manifest().k_multi;
        let mut rng = Rng::new(57);
        let phi = pm.layout.init_vector(&mut rng);
        let phis: Vec<f32> = (0..k)
            .flat_map(|ki| phi.iter().map(move |p| p + 0.02 * ki as f32))
            .collect();
        let lm = be.entry("tonn_micro", "loss_multi").unwrap();
        let mut xr = vec![0.0f32; lm.meta().input_len(1)];
        rng.fill_uniform(&mut xr, 0.05, 0.95);
        let base = lm.run1(&[&phis, &xr]).unwrap();
        for cap in [1usize, 2, 4, 64] {
            let o = EvalOptions::NONE
                .with_parallel(ParallelConfig {
                    threads: 8,
                    block_rows: 4,
                })
                .with_probe_workers(cap);
            assert_eq!(lm.run1_with(&[&phis, &xr], &o).unwrap(), base, "cap={cap}");
        }
    }

    /// Workers racing to materialize the SAME Φ must converge on one
    /// cache entry: a duplicate insert wastes a MAT_CACHE_SLOT and can
    /// evict a live probe entry mid-epoch (the double-insert race).
    #[test]
    fn materialization_cache_never_holds_duplicate_phis() {
        let be = NativeBackend::builtin();
        let pm = be.manifest().preset("tonn_micro").unwrap();
        let eval = be.eval("tonn_micro").unwrap().clone();
        let mut rng = Rng::new(91);
        let phi = pm.layout.init_vector(&mut rng);
        for round in 0..20 {
            eval.mat_cache.lock().unwrap().clear();
            std::thread::scope(|s| {
                for _ in 0..8 {
                    let eval = &eval;
                    let phi = &phi;
                    s.spawn(move || {
                        eval.materialized(phi);
                    });
                }
            });
            let n = eval.mat_cache.lock().unwrap().len();
            assert_eq!(n, 1, "round {round}: duplicate Φ entries in the cache");
        }
    }

    #[test]
    fn manifest_roundtrip_through_disk() {
        // builtin presets survive a manifest.json round-trip (the on-disk
        // path the python AOT build also produces)
        let be = NativeBackend::builtin();
        let pm = be.manifest().preset("tonn_micro").unwrap();
        let dir = std::env::temp_dir().join(format!("pp_native_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let (arch, layout) = (pm.arch.clone(), &pm.layout);
        // minimal manifest for this preset, segments via the one shared
        // serialization (Layout::segments_json, inverse of Layout::parse)
        let doc = Value::obj(vec![
            ("version", Value::Num(1.0)),
            (
                "batch_shapes",
                Value::obj(vec![
                    ("forward", Value::Num(B_FWD as f64)),
                    ("residual", Value::Num(B_RES as f64)),
                    ("validate", Value::Num(B_VAL as f64)),
                    ("k_multi", Value::Num(K_MULTI as f64)),
                ]),
            ),
            (
                "presets",
                Value::obj(vec![(
                    "tonn_micro",
                    Value::obj(vec![
                        (
                            "pde",
                            Value::obj(vec![(
                                "name",
                                Value::Str("poisson2".into()),
                            )]),
                        ),
                        ("param_dim", Value::Num(layout.param_dim as f64)),
                        ("segments", layout.segments_json()),
                        ("arch", arch),
                        (
                            "hyper",
                            Value::obj(vec![
                                ("fd_h", Value::Num(0.05)),
                                ("spsa_mu", Value::Num(0.02)),
                                ("spsa_n", Value::Num(10.0)),
                                ("lr", Value::Num(0.02)),
                                ("lr_decay", Value::Num(0.3)),
                                ("lr_decay_every", Value::Num(600.0)),
                                ("epochs", Value::Num(10.0)),
                                ("batch", Value::Num(B_RES as f64)),
                                ("k_multi", Value::Num(K_MULTI as f64)),
                            ]),
                        ),
                        (
                            "entries",
                            Value::obj(vec![(
                                "loss",
                                Value::obj(vec![
                                    (
                                        "inputs",
                                        Value::Arr(vec![
                                            Value::obj(vec![
                                                ("name", Value::Str("phi".into())),
                                                (
                                                    "shape",
                                                    arr_usize(&[layout.param_dim]),
                                                ),
                                            ]),
                                            Value::obj(vec![
                                                ("name", Value::Str("xr".into())),
                                                ("shape", arr_usize(&[B_RES, 2])),
                                            ]),
                                        ]),
                                    ),
                                    ("outputs", Value::Arr(vec![Value::Arr(vec![])])),
                                ]),
                            )]),
                        ),
                    ]),
                )]),
            ),
        ]);
        std::fs::write(dir.join("manifest.json"), doc.to_string()).unwrap();
        let loaded = NativeBackend::load(&dir).unwrap();
        assert_eq!(
            loaded.manifest().preset("tonn_micro").unwrap().layout.param_dim,
            layout.param_dim
        );
        // and it evaluates
        let loss = loaded.entry("tonn_micro", "loss").unwrap();
        let mut rng = Rng::new(1);
        let phi = layout.init_vector(&mut rng);
        let mut xr = vec![0.0f32; loss.meta().input_len(1)];
        rng.fill_uniform(&mut xr, 0.1, 0.9);
        assert!(loss.run_scalar(&[&phi, &xr]).unwrap().is_finite());
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A fused cross-job pass must reproduce each job's isolated
    /// batched dispatch bit for bit — FD and Stein jobs mixed in one
    /// pass, with distinct per-job boundary weights (`tonn_micro_ac`)
    /// riding along, and unhonorable overrides failing loudly.
    #[test]
    fn fused_cross_job_pass_matches_unfused_bitwise() {
        let be = NativeBackend::builtin();
        for preset in ["tonn_micro", "tonn_micro_ac"] {
            let pm = be.manifest().preset(preset).unwrap();
            let d = pm.layout.param_dim;
            let mut rng = Rng::new(29);
            let lm = be.entry(preset, "loss_multi").unwrap();
            let sm = be.entry(preset, "loss_stein_multi").unwrap();
            // three jobs: distinct Φ blocks, batches and options
            let mut data = Vec::new();
            for jidx in 0..3u32 {
                let mut phis = vec![0.0f32; K_MULTI * d];
                rng.fill_normal(&mut phis);
                let mut xr = vec![0.0f32; lm.meta().input_len(1)];
                rng.fill_uniform(&mut xr, 0.05, 0.95);
                let mut z = vec![0.0f32; sm.meta().input_len(2)];
                rng.fill_normal(&mut z);
                let opts = if preset == "tonn_micro_ac" {
                    EvalOptions::NONE.with_bc_weight(0.5 + jidx as f32)
                } else {
                    EvalOptions::NONE
                };
                data.push((phis, xr, z, opts));
            }
            let jobs: Vec<FusedLossJob> = data
                .iter()
                .enumerate()
                .map(|(i, (phis, xr, z, opts))| FusedLossJob {
                    kind: if i == 1 {
                        FusedLossKind::Stein
                    } else {
                        FusedLossKind::Fd
                    },
                    phis,
                    k: K_MULTI,
                    xr,
                    z,
                    opts: *opts,
                })
                .collect();
            let fused = be.loss_fused(preset, &jobs).unwrap();
            assert_eq!(fused.len(), jobs.len());
            for (i, j) in jobs.iter().enumerate() {
                let solo = match j.kind {
                    FusedLossKind::Fd => lm.run1_with(&[j.phis, j.xr], &j.opts).unwrap(),
                    FusedLossKind::Stein => {
                        sm.run1_with(&[j.phis, j.xr, j.z], &j.opts).unwrap()
                    }
                };
                assert_eq!(fused[i], solo, "{preset} job {i}: fused pass drifted");
            }
            if preset == "tonn_micro" {
                // a boundary weight on a hard-constrained problem must
                // fail the whole pass loudly, naming the offending job
                let mut bad = jobs.clone();
                bad[2].opts = EvalOptions::NONE.with_bc_weight(1.0);
                let err = format!("{:#}", be.loss_fused(preset, &bad).unwrap_err());
                assert!(err.contains("fused job 2"), "{err}");
                assert!(err.contains("no soft constraints"), "{err}");
            }
        }
    }

    /// An explicit `--precision f32` must be the default tier, bit for
    /// bit: the F32 path IS the engine that every golden fixture pins.
    #[test]
    fn precision_f32_explicit_is_bit_identical_to_default() {
        let be = NativeBackend::builtin();
        for preset in ["tonn_micro", "tonn_micro_ac"] {
            let pm = be.manifest().preset(preset).unwrap();
            let mut rng = Rng::new(61);
            let phi = pm.layout.init_vector(&mut rng);
            let o32 = EvalOptions::NONE.with_precision(EvalPrecision::F32);
            for entry_name in ["forward", "loss", "loss_stein"] {
                let e = be.entry(preset, entry_name).unwrap();
                let mut xs = vec![0.0f32; e.meta().input_len(1)];
                rng.fill_uniform(&mut xs, 0.05, 0.95);
                let mut z = vec![0.0f32; e.meta().inputs.get(2).map_or(0, |_| e.meta().input_len(2))];
                rng.fill_normal(&mut z);
                let ins: Vec<&[f32]> = if z.is_empty() {
                    vec![&phi, &xs]
                } else {
                    vec![&phi, &xs, &z]
                };
                let base = e.run(&ins).unwrap();
                let explicit = e.run_with(&ins, &o32).unwrap();
                assert_eq!(base, explicit, "{preset}/{entry_name}: explicit f32 drifted");
            }
        }
    }

    /// The f64 oracle tier must stay close to the default f32 engine:
    /// same math at higher precision, so losses agree within a loose
    /// rounding budget (exact bit equality is NOT expected).
    #[test]
    fn precision_f64_oracle_tracks_f32_within_bound() {
        let be = NativeBackend::builtin();
        let pm = be.manifest().preset("tonn_micro").unwrap();
        let mut rng = Rng::new(67);
        let phi = pm.layout.init_vector(&mut rng);
        let o64 = EvalOptions::NONE.with_precision(EvalPrecision::F64);

        let fwd = be.entry("tonn_micro", "forward").unwrap();
        let mut x = vec![0.0f32; fwd.meta().input_len(1)];
        rng.fill_uniform(&mut x, 0.0, 1.0);
        let u32_ = fwd.run1(&[&phi, &x]).unwrap();
        let u64_ = fwd.run1_with(&[&phi, &x], &o64).unwrap();
        assert_eq!(u32_.len(), u64_.len());
        for (i, (a, b)) in u32_.iter().zip(&u64_).enumerate() {
            assert!(
                (a - b).abs() <= 1e-3 * a.abs().max(1.0),
                "row {i}: f32 {a} vs f64 {b}"
            );
        }
        // hard Dirichlet rows are exactly zero in every tier
        let mut xb = x.clone();
        xb[0] = 0.0;
        assert_eq!(fwd.run1_with(&[&phi, &xb], &o64).unwrap()[0], 0.0);

        let loss = be.entry("tonn_micro", "loss").unwrap();
        let mut xr = vec![0.0f32; loss.meta().input_len(1)];
        rng.fill_uniform(&mut xr, 0.05, 0.95);
        let l32 = loss.run_scalar(&[&phi, &xr]).unwrap();
        let l64 = loss.run_scalar_with(&[&phi, &xr], &o64).unwrap();
        assert!(l64.is_finite() && l64 >= 0.0);
        assert!(
            (l32 - l64).abs() <= 0.05 * l64.abs().max(1.0),
            "loss tiers diverged: f32 {l32} vs f64 {l64}"
        );
        // the oracle is deterministic like every other tier
        assert_eq!(l64, loss.run_scalar_with(&[&phi, &xr], &o64).unwrap());
    }

    /// Quantized tiers are deterministic (fixed per-tensor grid, cached
    /// per bit depth), approach the f32 engine as bits grow, and refuse
    /// out-of-range bit depths loudly.
    #[test]
    fn precision_quantized_is_deterministic_and_bounded() {
        let be = NativeBackend::builtin();
        let pm = be.manifest().preset("tonn_micro").unwrap();
        let mut rng = Rng::new(71);
        let phi = pm.layout.init_vector(&mut rng);
        let loss = be.entry("tonn_micro", "loss").unwrap();
        let mut xr = vec![0.0f32; loss.meta().input_len(1)];
        rng.fill_uniform(&mut xr, 0.05, 0.95);
        let l32 = loss.run_scalar(&[&phi, &xr]).unwrap();

        let q16 = EvalOptions::NONE.with_precision(EvalPrecision::Quantized { bits: 16 });
        let lq = loss.run_scalar_with(&[&phi, &xr], &q16).unwrap();
        assert!(lq.is_finite() && lq >= 0.0);
        assert_eq!(lq, loss.run_scalar_with(&[&phi, &xr], &q16).unwrap());
        // documented bound: 16-bit weights stay within 25% of the engine
        assert!(
            (lq - l32).abs() <= 0.25 * l32.abs().max(1.0),
            "q16 loss out of bound: {lq} vs f32 {l32}"
        );
        // coarse grids drift further than fine ones (monotone in bits is
        // not guaranteed pointwise, but q3 must be the far outlier)
        let q3 = EvalOptions::NONE.with_precision(EvalPrecision::Quantized { bits: 3 });
        let lq3 = loss.run_scalar_with(&[&phi, &xr], &q3).unwrap();
        assert!(lq3.is_finite());
        assert!(
            (lq - l32).abs() <= (lq3 - l32).abs().max(1e-6),
            "q16 ({lq}) further from f32 ({l32}) than q3 ({lq3})"
        );

        // out-of-range depths are rejected at resolve time, loudly
        for bits in [0u8, 1, 25] {
            let bad = EvalOptions::NONE.with_precision(EvalPrecision::Quantized { bits });
            let err = format!("{:#}", loss.run_scalar_with(&[&phi, &xr], &bad).unwrap_err());
            assert!(err.contains("out of range"), "bits={bits}: {err}");
        }
    }

    /// A fused pass must refuse jobs whose resolved precisions differ —
    /// one materialized operand set serves the whole gang, so a mixed
    /// gang would silently evaluate some jobs in the wrong tier.
    #[test]
    fn precision_fused_pass_rejects_mixed_tiers() {
        let be = NativeBackend::builtin();
        let pm = be.manifest().preset("tonn_micro").unwrap();
        let d = pm.layout.param_dim;
        let mut rng = Rng::new(83);
        let lm = be.entry("tonn_micro", "loss_multi").unwrap();
        let mut phis = vec![0.0f32; K_MULTI * d];
        rng.fill_normal(&mut phis);
        let mut xr = vec![0.0f32; lm.meta().input_len(1)];
        rng.fill_uniform(&mut xr, 0.05, 0.95);
        let z: Vec<f32> = Vec::new();
        let job = |opts: EvalOptions| FusedLossJob {
            kind: FusedLossKind::Fd,
            phis: &phis,
            k: K_MULTI,
            xr: &xr,
            z: &z,
            opts,
        };

        // explicit F32 next to default (= F32) fuses fine
        let ok = be
            .loss_fused(
                "tonn_micro",
                &[job(EvalOptions::NONE), job(EvalOptions::NONE.with_precision(EvalPrecision::F32))],
            )
            .unwrap();
        assert_eq!(ok.len(), 2);
        assert_eq!(ok[0], ok[1]);

        // F64 next to default must fail loudly, naming the tiers
        let err = format!(
            "{:#}",
            be.loss_fused(
                "tonn_micro",
                &[job(EvalOptions::NONE), job(EvalOptions::NONE.with_precision(EvalPrecision::F64))],
            )
            .unwrap_err()
        );
        assert!(err.contains("mixed-precision"), "{err}");
        assert!(err.contains("f64"), "{err}");

        // a uniformly-quantized gang is fine — uniformity, not F32, is
        // the requirement
        let q = EvalOptions::NONE.with_precision(EvalPrecision::Quantized { bits: 16 });
        let okq = be.loss_fused("tonn_micro", &[job(q), job(q)]).unwrap();
        assert_eq!(okq[0], okq[1]);
    }
}
