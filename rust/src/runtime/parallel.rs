//! Evaluation-engine parallelism: row-block and probe fan-out over the
//! persistent worker pool ([`super::pool`]).
//!
//! The native backend evaluates batches row-independently (every network
//! output depends only on its own input row), so a batch can be cut into
//! contiguous row-blocks and the blocks distributed across workers with
//! NO change to the arithmetic: each row is computed by exactly the same
//! instruction sequence regardless of how the batch is partitioned.
//! That is the engine's correctness contract — **parallel ≡ sequential,
//! bit for bit** — and it is what lets the jax golden fixtures run
//! against the parallel path unchanged (`tests/artifact_numerics.rs`).
//!
//! Pieces:
//!
//! * [`ParallelConfig`] — the user-facing knob (`threads` x `block_rows`)
//!   threaded through [`super::Backend`], the trainer, the validator and
//!   the solver service.
//! * [`ParallelCtl`] — the atomic cell a backend shares with its cached
//!   entries so the config is runtime-tunable without rebuilding them.
//! * [`for_row_blocks`] — the row-block dispatch driver. Blocks become
//!   tasks on the shared [`super::pool`] (persistent parked std
//!   threads; the repo substrate stays tokio-free, DESIGN.md
//!   §Substitutions), with the fan-out width capped at the pool's
//!   global thread budget. The pre-pool driver — fresh scoped threads
//!   per call — is retained verbatim behind `PHOTON_FORCE_SCOPED=1`
//!   ([`super::pool::force_scoped`]) as the bit-equality oracle.
//! * [`for_probes`] / [`probe_split`] — the OUTER level of the training
//!   hot path's two-level parallelism: a ZO epoch is K = N+1 fully
//!   independent loss evaluations at different phase settings (paper
//!   Eq. 5), so the K probes fan out across workers and each probe's
//!   row-block evaluation runs on its share of the same thread budget.
//!   Each probe computes exactly what it would sequentially (row
//!   blocking never changes a probe's bits — see above), so
//!   probe-parallel ≡ probe-sequential bit for bit as well. The fused
//!   cross-job pass ([`super::Backend::loss_fused`]) reuses this same
//!   probe fan-out with the probes of SEVERAL same-preset jobs
//!   flattened into one lane list — same kernel per probe, same
//!   bit-exactness contract, one shared thread budget instead of
//!   per-job contention.
//!
//! Both fan-out levels submit to the ONE process-wide pool, so N
//! concurrent solver-service jobs cooperatively divide the budget's
//! cores instead of each spawning `threads` of their own — and the
//! per-dispatch spawn/join cost (tens of µs under the scoped driver,
//! real for micro presets and the K-small-dispatch training hot path)
//! is gone: `benches/latency.rs` pins pool ≥ scoped at the gated sizes.

use std::sync::atomic::{AtomicUsize, Ordering};

use super::pool;

/// Default rows per work block: sized so a block's activations stay
/// cache-resident for the repro-scale hidden widths while still cutting
/// the standard batches (100·43 stencil rows, 1024 validation rows) into
/// enough blocks to feed every worker.
pub const DEFAULT_BLOCK_ROWS: usize = 32;

/// Evaluation-engine parallelism settings.
///
/// `threads == 1` is the sequential engine; results are identical for
/// every value of both fields (see the module docs), so these trade
/// latency only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelConfig {
    /// worker threads per batch evaluation (per-dispatch fan-out width;
    /// additionally capped at the [`super::pool`] thread budget)
    pub threads: usize,
    /// contiguous rows per work block
    pub block_rows: usize,
}

impl ParallelConfig {
    /// The sequential engine (single thread, default blocking).
    pub fn sequential() -> ParallelConfig {
        ParallelConfig {
            threads: 1,
            block_rows: DEFAULT_BLOCK_ROWS,
        }
    }

    /// `threads` workers with the default block size.
    pub fn with_threads(threads: usize) -> ParallelConfig {
        ParallelConfig {
            threads: threads.max(1),
            block_rows: DEFAULT_BLOCK_ROWS,
        }
    }

    /// Hardware-sized default: `PHOTON_THREADS` / `PHOTON_BLOCK_ROWS`
    /// env overrides, else one worker per available core. The pool's
    /// global budget resolves this ONCE at init ([`super::pool`]) — per
    /// dispatch only the plain struct fields are read.
    pub fn auto() -> ParallelConfig {
        let threads = std::env::var("PHOTON_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            });
        let block_rows = std::env::var("PHOTON_BLOCK_ROWS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .unwrap_or(DEFAULT_BLOCK_ROWS);
        ParallelConfig {
            threads: threads.max(1),
            block_rows: block_rows.max(1),
        }
    }
}

/// Shared, runtime-tunable parallel settings (plain atomics, so the
/// backend and every cached entry can share one `Arc<ParallelCtl>` and
/// stay `Send + Sync`).
#[derive(Debug)]
pub struct ParallelCtl {
    threads: AtomicUsize,
    block_rows: AtomicUsize,
}

impl ParallelCtl {
    pub fn new(cfg: ParallelConfig) -> ParallelCtl {
        ParallelCtl {
            threads: AtomicUsize::new(cfg.threads.max(1)),
            block_rows: AtomicUsize::new(cfg.block_rows.max(1)),
        }
    }

    pub fn get(&self) -> ParallelConfig {
        ParallelConfig {
            threads: self.threads.load(Ordering::Relaxed),
            block_rows: self.block_rows.load(Ordering::Relaxed),
        }
    }

    pub fn set(&self, cfg: ParallelConfig) {
        self.threads.store(cfg.threads.max(1), Ordering::Relaxed);
        self.block_rows
            .store(cfg.block_rows.max(1), Ordering::Relaxed);
    }
}

/// Cut `out` (a flat batch of `out.len() / row_len` rows) into blocks of
/// `cfg.block_rows` rows and run `eval(first_row, block)` on every block,
/// fanned out across up to `cfg.threads` workers of the shared
/// [`super::pool`] (capped at the pool's global thread budget).
///
/// Blocks are assigned round-robin (block `i` -> lane `i % workers`),
/// mirroring the scoped driver's static partition; pool participants may
/// additionally STEAL blocks from other lanes, which is pure scheduling —
/// because `eval` must compute each row independently of the blocking,
/// the result is identical for every `ParallelConfig`, every driver and
/// every steal order. Small batches (one block) and `threads == 1` stay
/// on the calling thread, touching no pool state.
///
/// `PHOTON_FORCE_SCOPED=1` (or [`super::pool::set_force_scoped`]) pins
/// the pre-pool oracle driver: fresh scoped threads per call, uncapped
/// by the pool budget. `tests/pool_equivalence.rs` holds the two
/// drivers bit-equal across the preset registry.
pub fn for_row_blocks<F>(cfg: ParallelConfig, row_len: usize, out: &mut [f32], eval: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    assert!(row_len > 0, "for_row_blocks: row_len must be positive");
    let rows = out.len() / row_len;
    assert_eq!(rows * row_len, out.len(), "for_row_blocks: ragged batch");
    let block = cfg.block_rows.max(1);
    let threads = cfg.threads.max(1);
    let chunk = block * row_len;
    let force_scoped = pool::force_scoped();
    let mut workers = threads;
    if threads > 1 && rows > block && !force_scoped {
        // Only a real fan-out consults the budget (the first query is
        // what lazily starts the pool).
        workers = threads.min(pool::budget());
    }
    if workers == 1 || rows <= block {
        let mut row0 = 0;
        for c in out.chunks_mut(chunk) {
            let nr = c.len() / row_len;
            eval(row0, c);
            row0 += nr;
        }
        return;
    }
    let n_blocks = rows / block + usize::from(rows % block != 0);
    let workers = workers.min(n_blocks);
    if force_scoped {
        let mut assignments: Vec<Vec<(usize, &mut [f32])>> =
            (0..workers).map(|_| Vec::new()).collect();
        for (bi, c) in out.chunks_mut(chunk).enumerate() {
            assignments[bi % workers].push((bi * block, c));
        }
        let eval = &eval;
        std::thread::scope(|s| {
            for list in assignments {
                s.spawn(move || {
                    for (row0, c) in list {
                        eval(row0, c);
                    }
                });
            }
        });
        return;
    }
    let eval = &eval;
    let mut lanes: Vec<Vec<pool::Task<'_>>> = (0..workers).map(|_| Vec::new()).collect();
    for (bi, c) in out.chunks_mut(chunk).enumerate() {
        let row0 = bi * block;
        lanes[bi % workers].push(Box::new(move || eval(row0, c)));
    }
    pool::run(lanes);
}

/// Split one engine thread budget across `k` concurrent probe
/// evaluations: returns `(probe_workers, inner_cfg)` where
/// `probe_workers ≤ min(threads, k)` probes run at once and each runs
/// its row-block evaluation with `inner_cfg` (`threads / probe_workers`
/// workers), so total thread pressure never exceeds `cfg.threads`.
pub fn probe_split(cfg: ParallelConfig, k: usize) -> (usize, ParallelConfig) {
    probe_split_capped(cfg, k, None)
}

/// [`probe_split`] with an optional cap on concurrent probe lanes (the
/// `EvalOptions::probe_workers` budget of a dispatch): at most `cap`
/// probes run at once, each inheriting a correspondingly larger share
/// of the thread budget. Latency only — results never depend on the
/// split.
pub fn probe_split_capped(
    cfg: ParallelConfig,
    k: usize,
    cap: Option<usize>,
) -> (usize, ParallelConfig) {
    let threads = cfg.threads.max(1);
    let mut workers = threads.min(k.max(1));
    if let Some(c) = cap {
        workers = workers.min(c.max(1));
    }
    (
        workers,
        ParallelConfig {
            threads: (threads / workers).max(1),
            block_rows: cfg.block_rows.max(1),
        },
    )
}

/// Evaluate `out.len()` independent probes, `out[i] = eval(i, inner)`,
/// fanned out across [`probe_split`]'s probe workers (round-robin,
/// static partition — same scheduling discipline as [`for_row_blocks`]).
///
/// `eval` receives the per-probe engine config it should evaluate with.
/// Because a probe's result may not depend on its engine config (the
/// row-block contract above), the output is identical for every
/// `ParallelConfig` — probe-parallel ≡ sequential, bit for bit. With
/// one worker (or one probe) everything stays on the calling thread and
/// `eval` gets the full budget.
pub fn for_probes<F>(cfg: ParallelConfig, out: &mut [f32], eval: F)
where
    F: Fn(usize, ParallelConfig) -> f32 + Sync,
{
    for_probes_capped(cfg, None, out, eval);
}

/// [`for_probes`] with an optional cap on concurrent probe lanes (see
/// [`probe_split_capped`]): fewer probes run at once, each on a larger
/// inner thread budget. Bit-identical to the uncapped fan-out for every
/// `cap` — the probe-parallel contract is split-independent.
///
/// Probe tasks go to the same shared [`super::pool`] as the row blocks
/// (the pool budget further caps the lanes, refunding the freed budget
/// to each probe's inner config); the scoped oracle driver sits behind
/// `PHOTON_FORCE_SCOPED=1`, as in [`for_row_blocks`].
pub fn for_probes_capped<F>(cfg: ParallelConfig, cap: Option<usize>, out: &mut [f32], eval: F)
where
    F: Fn(usize, ParallelConfig) -> f32 + Sync,
{
    let k = out.len();
    let force_scoped = pool::force_scoped();
    let (mut workers, mut inner) = probe_split_capped(cfg, k, cap);
    if workers > 1 && !force_scoped {
        let budget = pool::budget();
        if budget < workers {
            let capped = cap.unwrap_or(usize::MAX).min(budget);
            (workers, inner) = probe_split_capped(cfg, k, Some(capped));
        }
    }
    if workers <= 1 {
        for (i, o) in out.iter_mut().enumerate() {
            *o = eval(i, cfg);
        }
        return;
    }
    if force_scoped {
        let mut lanes: Vec<Vec<(usize, &mut f32)>> = (0..workers).map(|_| Vec::new()).collect();
        for (i, o) in out.iter_mut().enumerate() {
            lanes[i % workers].push((i, o));
        }
        let eval = &eval;
        std::thread::scope(|s| {
            for lane in lanes {
                s.spawn(move || {
                    for (i, o) in lane {
                        *o = eval(i, inner);
                    }
                });
            }
        });
        return;
    }
    let eval = &eval;
    let mut lanes: Vec<Vec<pool::Task<'_>>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, o) in out.iter_mut().enumerate() {
        lanes[i % workers].push(Box::new(move || *o = eval(i, inner)));
    }
    pool::run(lanes);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_clamps_and_env_free_constructors() {
        let s = ParallelConfig::sequential();
        assert_eq!(s.threads, 1);
        assert_eq!(ParallelConfig::with_threads(0).threads, 1);
        let ctl = ParallelCtl::new(ParallelConfig {
            threads: 0,
            block_rows: 0,
        });
        assert_eq!(
            ctl.get(),
            ParallelConfig {
                threads: 1,
                block_rows: 1
            }
        );
        ctl.set(ParallelConfig {
            threads: 3,
            block_rows: 8,
        });
        assert_eq!(ctl.get().threads, 3);
        assert_eq!(ctl.get().block_rows, 8);
    }

    /// Every (threads, block_rows) partition must visit each row exactly
    /// once with the right global row index.
    #[test]
    fn row_blocks_cover_every_row_once() {
        for &(threads, block_rows) in
            &[(1usize, 4usize), (2, 4), (3, 1), (4, 5), (8, 3), (2, 1000)]
        {
            for rows in [0usize, 1, 4, 5, 31, 32, 33, 100] {
                let row_len = 3;
                let mut out = vec![0.0f32; rows * row_len];
                for_row_blocks(
                    ParallelConfig {
                        threads,
                        block_rows,
                    },
                    row_len,
                    &mut out,
                    |row0, block| {
                        for (r, row) in block.chunks_mut(row_len).enumerate() {
                            for (j, v) in row.iter_mut().enumerate() {
                                *v += ((row0 + r) * row_len + j) as f32 + 1.0;
                            }
                        }
                    },
                );
                for (i, v) in out.iter().enumerate() {
                    assert_eq!(
                        *v,
                        i as f32 + 1.0,
                        "threads={threads} block={block_rows} rows={rows} idx={i}"
                    );
                }
            }
        }
    }

    /// The probe budget split never oversubscribes and never starves.
    #[test]
    fn probe_split_respects_thread_budget() {
        for (threads, k, want_workers, want_inner) in [
            (1usize, 11usize, 1usize, 1usize),
            (4, 11, 4, 1),
            (8, 11, 8, 1),
            (16, 11, 11, 1),
            (22, 11, 11, 2),
            (8, 1, 1, 8),
            (8, 2, 2, 4),
            (3, 0, 1, 3),
        ] {
            let (workers, inner) =
                probe_split(ParallelConfig { threads, block_rows: 32 }, k);
            assert_eq!(workers, want_workers, "threads={threads} k={k}");
            assert_eq!(inner.threads, want_inner, "threads={threads} k={k}");
            assert!(workers * inner.threads <= threads.max(1));
        }
    }

    /// Every probe is visited exactly once with its own index, and the
    /// parallel fan-out equals the sequential loop bit for bit.
    #[test]
    fn probes_cover_every_index_and_match_sequential() {
        let eval = |i: usize, _inner: ParallelConfig| ((i as f32) * 1.33).sin();
        for k in [0usize, 1, 2, 11, 23] {
            let mut seq = vec![0.0f32; k];
            for_probes(ParallelConfig { threads: 1, block_rows: 4 }, &mut seq, eval);
            for threads in [2, 4, 8, 64] {
                let mut par = vec![0.0f32; k];
                for_probes(ParallelConfig { threads, block_rows: 4 }, &mut par, eval);
                assert_eq!(seq, par, "k={k} threads={threads}");
            }
        }
    }

    /// The probe-lane cap (`EvalOptions::probe_workers`) bounds
    /// concurrency, refunds the thread budget to the inner config, and
    /// never changes the output bits.
    #[test]
    fn capped_probe_fanout_matches_sequential() {
        let eval = |i: usize, _inner: ParallelConfig| ((i as f32) * 0.71).cos();
        let mut seq = vec![0.0f32; 11];
        for_probes(
            ParallelConfig {
                threads: 1,
                block_rows: 4,
            },
            &mut seq,
            eval,
        );
        for cap in [Some(1), Some(2), Some(5), Some(64), None] {
            let mut par = vec![0.0f32; 11];
            for_probes_capped(
                ParallelConfig {
                    threads: 8,
                    block_rows: 4,
                },
                cap,
                &mut par,
                eval,
            );
            assert_eq!(seq, par, "cap={cap:?}");
        }
        let (w, inner) = probe_split_capped(
            ParallelConfig {
                threads: 8,
                block_rows: 4,
            },
            11,
            Some(2),
        );
        assert_eq!(w, 2, "cap must bound the probe lanes");
        assert_eq!(inner.threads, 4, "capped lanes inherit the freed budget");
        assert!(w * inner.threads <= 8);
    }

    /// Nested use — probes fanning out row blocks on their inner budget
    /// — still produces the sequential result.
    #[test]
    fn probes_nest_row_blocks() {
        let rows = 37;
        let probe_eval = |i: usize, inner: ParallelConfig| -> f32 {
            let mut buf = vec![0.0f32; rows];
            for_row_blocks(inner, 1, &mut buf, |row0, block| {
                for (r, v) in block.iter_mut().enumerate() {
                    *v = ((row0 + r) as f32 + i as f32 * 0.1).cos();
                }
            });
            buf.iter().sum()
        };
        let mut seq = vec![0.0f32; 7];
        for_probes(ParallelConfig::sequential(), &mut seq, probe_eval);
        let mut par = vec![0.0f32; 7];
        for_probes(ParallelConfig { threads: 6, block_rows: 5 }, &mut par, probe_eval);
        assert_eq!(seq, par);
    }

    /// The pool and scoped-oracle drivers produce bit-identical buffers
    /// for both fan-out levels — the contract `PHOTON_FORCE_SCOPED=1`
    /// exists to check. Restores the env-resolved driver afterwards, so
    /// it composes with a forced-scoped CI leg.
    #[test]
    fn pool_and_scoped_drivers_agree() {
        let cfg = ParallelConfig {
            threads: 4,
            block_rows: 5,
        };
        let row_eval = |row0: usize, block: &mut [f32]| {
            for (r, v) in block.iter_mut().enumerate() {
                *v = ((row0 + r) as f32 * 0.37).sin();
            }
        };
        let probe_eval = |i: usize, _inner: ParallelConfig| ((i as f32) * 0.91).cos();
        let run_both = |scoped: bool| -> (Vec<f32>, Vec<f32>) {
            pool::set_force_scoped(scoped);
            let mut rows = vec![0.0f32; 57];
            for_row_blocks(cfg, 1, &mut rows, row_eval);
            let mut probes = vec![0.0f32; 11];
            for_probes(cfg, &mut probes, probe_eval);
            (rows, probes)
        };
        let scoped = run_both(true);
        let pooled = run_both(false);
        pool::set_force_scoped(std::env::var("PHOTON_FORCE_SCOPED").as_deref() == Ok("1"));
        assert_eq!(scoped, pooled, "drivers must agree bitwise");
    }

    /// Parallel and sequential drivers produce identical buffers for a
    /// row-independent eval (the engine's core contract).
    #[test]
    fn parallel_matches_sequential() {
        let row_len = 7;
        let rows = 57;
        let eval = |row0: usize, block: &mut [f32]| {
            for (r, row) in block.chunks_mut(row_len).enumerate() {
                let g = (row0 + r) as f32;
                for (j, v) in row.iter_mut().enumerate() {
                    *v = (g * 1.25 + j as f32).sin();
                }
            }
        };
        let mut seq = vec![0.0f32; rows * row_len];
        for_row_blocks(ParallelConfig::sequential(), row_len, &mut seq, eval);
        for threads in [2, 4, 8] {
            let mut par = vec![0.0f32; rows * row_len];
            for_row_blocks(
                ParallelConfig {
                    threads,
                    block_rows: 5,
                },
                row_len,
                &mut par,
                eval,
            );
            assert_eq!(seq, par, "threads={threads}");
        }
    }
}
