//! Typecheck-only stand-in for the `xla` PJRT bindings.
//!
//! The real bindings ship with the XLA toolchain image and are not a
//! registry dependency, so the plain `--features pjrt` build compiles
//! [`super::pjrt`] against this stub instead: CI's feature-matrix job
//! keeps the whole PJRT path compile-checked (it can't silently rot),
//! while every runtime entry point reports that the real runtime is
//! absent. To link the real thing, add the `xla` dependency and build
//! with `--features pjrt-xla` (see rust/Cargo.toml).
#![allow(dead_code)]

use std::path::Path;

pub const STUB_MSG: &str = "xla PJRT bindings are not linked (typecheck stub): add the `xla` \
     dependency and build with `--features pjrt-xla` (rust/Cargo.toml)";

/// Mirrors the bindings' debug-printable error type.
#[derive(Debug)]
pub struct Error(pub &'static str);

pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error(STUB_MSG))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(Error(STUB_MSG))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error(STUB_MSG))
    }
}

pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error(STUB_MSG))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error(STUB_MSG))
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error(STUB_MSG))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error(STUB_MSG))
    }

    pub fn platform_name(&self) -> String {
        "pjrt-stub".to_string()
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto, Error> {
        Err(Error(STUB_MSG))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
