//! Bench A1: SPSA ablations — the sign de-noising (paper Eq. 6, claimed
//! to de-noise the SPSA estimate) and the sampling radius μ.
//!
//!     cargo bench --bench ablation_spsa

mod common;

use photon_pinn::coordinator::trainer::{OnChipTrainer, TrainConfig};
use photon_pinn::util::bench::Table;
use photon_pinn::util::stats::sci;

fn main() {
    let rt = common::runtime();
    let epochs = common::epochs(600);
    let mut t = Table::new(
        "A1 — SPSA update-rule & radius ablation (tonn_small, ZO on-chip)",
        &["update", "mu", "lr", "final val MSE", "best val MSE", "skipped"],
    );
    for (optimizer, mu, lr) in [
        ("zo-signsgd", 0.02, 0.02),   // the paper's configuration
        ("zo-sgd", 0.02, 0.02),       // no sign de-noising
        ("zo-sgd", 0.02, 0.002),      // no sign, tamer lr
        ("zo-signsgd", 0.1, 0.02),    // big radius
        ("zo-signsgd", 0.005, 0.02),  // small radius
    ] {
        let mut cfg = TrainConfig::from_manifest(&rt, "tonn_small").unwrap();
        cfg.epochs = epochs;
        cfg.optimizer = optimizer.into();
        cfg.spsa_mu = mu;
        cfg.lr = lr;
        cfg.validate_every = 50;
        let res = OnChipTrainer::new(&rt, cfg).unwrap().train().unwrap();
        t.row(&[
            optimizer.to_string(),
            mu.to_string(),
            lr.to_string(),
            sci(res.final_val as f64),
            sci(res.metrics.best_val().unwrap_or(f32::NAN) as f64),
            res.metrics.skipped_epochs.to_string(),
        ]);
    }
    t.print();
    println!("\npaper claim under test: sign de-noising stabilizes ZO training (Eq. 6).");
}
