//! Bench T2: regenerate the paper's Table 2 (#MZIs, energy, latency,
//! footprint for ONN / TONN-1 / TONN-2) and the §4.2 training-efficiency
//! paragraph, printing paper-vs-measured side by side.
//!
//!     cargo bench --bench table2

use photon_pinn::photonics::perf::{Design, NetworkDims, PerfModel, TrainingEfficiency};
use photon_pinn::util::bench::Table;
use photon_pinn::util::stats::sci;

struct PaperRow {
    design: &'static str,
    params: f64,
    mzis: f64,
    energy: Option<f64>,
    latency: f64,
    footprint: f64,
}

const PAPER: [PaperRow; 3] = [
    PaperRow { design: "ONN", params: 6.08e5, mzis: 2.10e6, energy: None, latency: 600.0, footprint: 2.62e5 },
    PaperRow { design: "TONN-1", params: 1.53e3, mzis: 1.79e3, energy: Some(6.45e-9), latency: 550.0, footprint: 648.0 },
    PaperRow { design: "TONN-2", params: 1.53e3, mzis: 28.0, energy: Some(5.05e-9), latency: 3604.0, footprint: 26.0 },
];

fn ratio(ours: f64, paper: f64) -> String {
    format!("{:.2}x", ours / paper)
}

fn main() {
    let model = PerfModel::default();
    let mut t = Table::new(
        "Table 2 — paper vs measured",
        &["Design", "metric", "paper", "measured", "ratio"],
    );
    for (row, (design, dims)) in PAPER.iter().zip([
        (Design::Onn, NetworkDims::paper_onn()),
        (Design::Tonn1, NetworkDims::paper_tonn()),
        (Design::Tonn2, NetworkDims::paper_tonn()),
    ]) {
        let r = model.report(design, &dims);
        t.row(&[row.design.into(), "params".into(), sci(row.params), sci(r.params as f64),
                ratio(r.params as f64, row.params)]);
        t.row(&[row.design.into(), "#MZIs".into(), sci(row.mzis), sci(r.mzis as f64),
                ratio(r.mzis as f64, row.mzis)]);
        t.row(&[
            row.design.into(),
            "energy/inf (J)".into(),
            row.energy.map(sci).unwrap_or_else(|| "-".into()),
            r.energy_per_inference_j.map(sci).unwrap_or_else(|| "infeasible".into()),
            match (row.energy, r.energy_per_inference_j) {
                (Some(p), Some(m)) => ratio(m, p),
                (None, None) => "both infeasible".into(),
                _ => "MISMATCH".into(),
            },
        ]);
        t.row(&[row.design.into(), "latency/inf (ns)".into(), format!("{:.0}", row.latency),
                format!("{:.0}", r.latency_per_inference_ns),
                ratio(r.latency_per_inference_ns, row.latency)]);
        t.row(&[row.design.into(), "footprint (mm2)".into(), sci(row.footprint),
                sci(r.footprint_mm2), ratio(r.footprint_mm2, row.footprint)]);
    }
    t.print();

    // headline: 1.17e3x MZI reduction
    let onn = model.mzi_count(Design::Onn, &NetworkDims::paper_onn()) as f64;
    let t1 = model.mzi_count(Design::Tonn1, &NetworkDims::paper_tonn()) as f64;
    println!("\nheadline MZI reduction: measured {:.3e}x (paper 1.17e3x)", onn / t1);

    // §4.2 training efficiency
    let te = TrainingEfficiency::paper();
    let dims = NetworkDims::paper_tonn();
    let e_inf = model.energy_j(Design::Tonn1, &dims).unwrap();
    let t_inf = model.latency_ns(Design::Tonn1, &dims);
    let (e_tot, t_tot) = te.totals(e_inf, t_inf);
    let mut t3 = Table::new(
        "§4.2 training efficiency — paper vs measured (TONN-1)",
        &["quantity", "paper", "measured"],
    );
    t3.row(&["inferences/epoch".into(), "4.20e4".into(), sci(te.inferences_per_epoch() as f64)]);
    t3.row(&["energy/epoch (J)".into(), "2.71e-4".into(), sci(te.energy_per_epoch_j(e_inf))]);
    t3.row(&["latency/epoch (s)".into(), "2.3e-4".into(), sci(te.latency_per_epoch_s(t_inf))]);
    t3.row(&["total energy (J)".into(), "1.36".into(), format!("{e_tot:.3}")]);
    t3.row(&["total time (s)".into(), "1.15".into(), format!("{t_tot:.3}")]);
    t3.print();
}
