//! Shared plumbing for the paper-table benches (harness = false).

use std::path::PathBuf;

use photon_pinn::runtime::Runtime;

/// Load the runtime or exit gracefully when artifacts are missing (so
/// `cargo bench` in a fresh checkout fails with a clear message).
#[allow(dead_code)]
pub fn runtime() -> Runtime {
    let dir = photon_pinn::resolve_artifacts_dir(None);
    match Runtime::load(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot load artifacts from {}: {e:#}\nrun `make artifacts` first", dir.display());
            std::process::exit(2);
        }
    }
}

/// Epoch budget knob: full paper-shaped runs by default, fast smoke runs
/// with PHOTON_BENCH_FAST=1 (used by CI-style checks).
#[allow(dead_code)]
pub fn epochs(full: usize) -> usize {
    if std::env::var("PHOTON_BENCH_FAST").as_deref() == Ok("1") {
        (full / 10).max(20)
    } else {
        full
    }
}

/// Output directory for CSV artifacts of figure benches.
#[allow(dead_code)]
pub fn out_dir() -> PathBuf {
    let d = PathBuf::from("bench_out");
    std::fs::create_dir_all(&d).ok();
    d
}
