//! Shared plumbing for the paper-table benches (harness = false).

use std::path::PathBuf;

use photon_pinn::runtime::Backend;

/// Load the default backend (native; AOT manifest when present) or exit
/// with a clear message if a broken manifest is on disk.
#[allow(dead_code)]
pub fn runtime() -> Box<dyn Backend> {
    let dir = photon_pinn::resolve_artifacts_dir(None);
    match photon_pinn::runtime::load_backend(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot load backend from {}: {e:#}", dir.display());
            std::process::exit(2);
        }
    }
}

/// True in CI-smoke mode (`PHOTON_BENCH_FAST=1`): tiny presets, fewer
/// iterations, reduced epoch budgets.
#[allow(dead_code)]
pub fn fast() -> bool {
    std::env::var("PHOTON_BENCH_FAST").as_deref() == Ok("1")
}

/// Epoch budget knob: full paper-shaped runs by default, fast smoke runs
/// with PHOTON_BENCH_FAST=1 (used by CI-style checks).
#[allow(dead_code)]
pub fn epochs(full: usize) -> usize {
    if fast() {
        (full / 10).max(20)
    } else {
        full
    }
}

/// Output directory for CSV artifacts of figure benches.
#[allow(dead_code)]
pub fn out_dir() -> PathBuf {
    let d = PathBuf::from("bench_out");
    std::fs::create_dir_all(&d).ok();
    d
}
