//! Bench P1: simulator hot-path latency — what the rust coordinator pays
//! per artifact dispatch (NOT photonic latency; that is Table 2's model).
//! Used by the §Perf optimization loop to find the bottleneck layer.
//!
//!     cargo bench --bench latency

mod common;

use photon_pinn::optim::Spsa;
use photon_pinn::pde::Sampler;
use photon_pinn::photonics::noise::{ChipRealization, NoiseConfig};
use photon_pinn::runtime::{Backend, Entry};
use photon_pinn::util::bench::{bench, report};
use photon_pinn::util::rng::Rng;

fn main() {
    let rt = common::runtime();
    let mut results = Vec::new();

    for preset in ["tonn_small", "onn_small", "tonn_paper"] {
        let Ok(pm) = rt.manifest().preset(preset) else { continue };
        let _d = pm.layout.param_dim;
        let mut rng = Rng::new(0);
        let phi = pm.layout.init_vector(&mut rng);
        let mut sampler = Sampler::new(pm.pde, 1);
        let mut xr = Vec::new();
        sampler.batch(rt.manifest().b_residual, &mut xr);
        let mut xf = Vec::new();
        sampler.batch(rt.manifest().b_forward, &mut xf);
        let (xv, uv) = sampler.validation(rt.manifest().b_validate);

        if let Ok(fwd) = rt.entry(preset, "forward") {
            results.push(bench(&format!("{preset}/forward (B=128, pallas path)"), 3, 20, || {
                fwd.run1(&[&phi, &xf]).unwrap();
            }));
        }
        if let Ok(loss) = rt.entry(preset, "loss") {
            results.push(bench(&format!("{preset}/loss (42xB FD fan-out)"), 3, 20, || {
                loss.run_scalar(&[&phi, &xr]).unwrap();
            }));
        }
        if let Ok(lm) = rt.entry(preset, "loss_multi") {
            let k = rt.manifest().k_multi;
            let phis: Vec<f32> = (0..k).flat_map(|_| phi.iter().copied()).collect();
            results.push(bench(&format!("{preset}/loss_multi (K=11 SPSA batch)"), 2, 10, || {
                lm.run1(&[&phis, &xr]).unwrap();
            }));
        }
        if let Ok(val) = rt.entry(preset, "validate") {
            results.push(bench(&format!("{preset}/validate (B=1024)"), 3, 20, || {
                val.run_scalar(&[&phi, &xv, &uv]).unwrap();
            }));
        }
    }

    // L3-side costs: everything the coordinator does *around* a dispatch
    {
        let pm = rt.manifest().preset("tonn_small").unwrap();
        let d = pm.layout.param_dim;
        let chip = ChipRealization::sample(&pm.layout, &NoiseConfig::default_chip(), 1);
        let spsa = Spsa::new(0.02, 10);
        let mut rng = Rng::new(2);
        let phi = pm.layout.init_vector(&mut rng);
        let (mut xi, mut settings, mut eff, mut eff_all, mut grad) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new());
        results.push(bench("L3/perturb+program (K=11, d=473)", 10, 200, || {
            spsa.sample_perturbations(d, &mut rng, &mut xi);
            spsa.build_settings(&phi, &xi, &mut settings);
            eff_all.clear();
            for i in 0..11 {
                chip.program(&settings[i * d..(i + 1) * d], &mut eff);
                eff_all.extend_from_slice(&eff);
            }
            std::hint::black_box(&eff_all);
        }));
        let losses = vec![0.5f32; 11];
        let xi2 = {
            let mut v = vec![0.0f32; 10 * d];
            Rng::new(3).fill_normal(&mut v);
            v
        };
        results.push(bench("L3/spsa estimate + sign step", 10, 500, || {
            spsa.estimate(&losses, &xi2, &mut grad);
            std::hint::black_box(&grad);
        }));
        let mut sampler = Sampler::new(pm.pde, 9);
        let mut xr = Vec::new();
        results.push(bench("L3/sample collocation batch (100x21)", 10, 500, || {
            sampler.batch(100, &mut xr);
            std::hint::black_box(&xr);
        }));
    }

    report(&results);
    println!("\nL3 overhead per training step = perturb+program + estimate + sampling;");
    println!("compare against the loss_multi dispatch above (DESIGN.md §Perf target: <10%).");
}
