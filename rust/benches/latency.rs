//! Bench P1: simulator hot-path latency — what the rust coordinator pays
//! per entry dispatch — plus the parallel evaluation engine's measured
//! speedup over (a) its own sequential (1-thread) path and (b) the PR-1
//! scalar reference path. Every case is merged into the machine-readable
//! `BENCH_native.json` (see `util::bench::BenchReport` for the schema),
//! which CI uploads per run so perf is comparable across PRs.
//!
//!     cargo bench --bench latency
//!
//! Environment knobs:
//! * `PHOTON_BENCH_FAST=1`    — tiny-preset smoke run (CI)
//! * `PHOTON_THREADS=N`       — engine threads for the parallel cases
//! * `PHOTON_BENCH_ENFORCE=1` — exit non-zero if the parallel engine is
//!   slower than the sequential engine on any sizable (non-micro)
//!   preset, or if the persistent worker pool is slower than the
//!   scoped-thread oracle driver on any gated pool-vs-scoped case
//! * `PHOTON_BENCH_OUT=path`  — report location (default: repo root)

mod common;

use photon_pinn::coordinator::trainer::{OnChipTrainer, TrainConfig};
use photon_pinn::optim::Spsa;
use photon_pinn::pde::Sampler;
use photon_pinn::photonics::noise::{ChipRealization, NoiseConfig};
use photon_pinn::runtime::{
    Backend, Entry, EvalOptions, EvalPrecision, NativeBackend, ParallelConfig,
};
use photon_pinn::tensor::simd;
use photon_pinn::util::bench::{bench, bench_report_path, report, BenchReport, BenchResult};
use photon_pinn::util::rng::Rng;

/// One measured entry: sequential engine, parallel engine, optional
/// PR-1 reference; the recorded speedups use the reference when present,
/// else the sequential engine.
struct EntryRuns {
    seq: BenchResult,
    par: BenchResult,
    reference: Option<BenchResult>,
}

fn main() {
    let fast = common::fast();
    let dir = photon_pinn::resolve_artifacts_dir(None);
    let rt = match NativeBackend::load_or_builtin(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("cannot load native backend from {}: {e:#}", dir.display());
            std::process::exit(2);
        }
    };
    let par_cfg = ParallelConfig::auto();
    let seq_cfg = ParallelConfig::sequential();
    let presets: &[&str] = if fast {
        &["tonn_micro", "tonn_small"]
    } else {
        &["tonn_small", "onn_small", "tonn_paper"]
    };

    let mut results: Vec<BenchResult> = Vec::new();
    let mut rep = BenchReport::new("latency", "native-cpu", par_cfg.threads, par_cfg.block_rows);
    // (case, par_median, seq_median) pairs the enforce gate checks
    let mut enforced: Vec<(String, f64, f64)> = Vec::new();

    for preset in presets {
        let Ok(pm) = rt.manifest().preset(preset) else { continue };
        let (warm, iters) = match (fast, *preset) {
            (true, _) => (1, 5),
            (false, "tonn_paper") => (1, 5),
            (false, _) => (3, 20),
        };
        let mut rng = Rng::new(0);
        let phi = pm.layout.init_vector(&mut rng);
        let mut sampler = Sampler::new(pm.pde.clone(), 1);
        let mut xr = Vec::new();
        sampler.batch(rt.manifest().b_residual, &mut xr);
        let mut xf = Vec::new();
        sampler.batch(rt.manifest().b_forward, &mut xf);
        let (xv, uv) = sampler.validation(rt.manifest().b_validate);

        // micro presets have too little work per dispatch for threads to
        // pay off — record them, but keep them out of the enforce gate
        let enforceable = !preset.contains("micro");

        let measure = |name: &str,
                           reference: Option<BenchResult>,
                           run: &mut dyn FnMut()|
         -> EntryRuns {
            rt.set_parallel(seq_cfg);
            let seq = bench(&format!("{name} engine seq(1T)"), warm, iters, &mut *run);
            rt.set_parallel(par_cfg);
            let par = bench(
                &format!("{name} engine par({}T)", par_cfg.threads),
                warm,
                iters,
                run,
            );
            EntryRuns {
                seq,
                par,
                reference,
            }
        };

        let mut record = |rep: &mut BenchReport, runs: EntryRuns| {
            let base = runs.reference.as_ref().unwrap_or(&runs.seq);
            rep.case_vs(&runs.seq, runs.reference.as_ref());
            rep.case_vs(&runs.par, Some(base));
            if enforceable {
                enforced.push((
                    runs.par.name.clone(),
                    runs.par.median_s,
                    runs.seq.median_s,
                ));
            }
            if let Some(r) = runs.reference {
                results.push(r);
            }
            results.push(runs.seq);
            results.push(runs.par);
        };

        if let Ok(fwd) = rt.entry(preset, "forward") {
            let reference = bench(
                &format!("{preset}/forward reference(PR-1)"),
                warm,
                iters,
                || {
                    rt.forward_reference(preset, &phi, &xf).unwrap();
                },
            );
            let runs = measure(&format!("{preset}/forward (B=128)"), Some(reference), &mut || {
                fwd.run1(&[&phi, &xf]).unwrap();
            });
            record(&mut rep, runs);
        }
        if let Ok(loss) = rt.entry(preset, "loss") {
            let reference = bench(
                &format!("{preset}/loss reference(PR-1)"),
                warm,
                iters,
                || {
                    rt.loss_reference(preset, &phi, &xr).unwrap();
                },
            );
            let runs = measure(
                &format!("{preset}/loss (42xB FD fan-out)"),
                Some(reference),
                &mut || {
                    loss.run_scalar(&[&phi, &xr]).unwrap();
                },
            );
            record(&mut rep, runs);
        }
        if let Ok(lm) = rt.entry(preset, "loss_multi") {
            let k = rt.manifest().k_multi;
            let phis: Vec<f32> = (0..k).flat_map(|_| phi.iter().copied()).collect();
            let runs = measure(
                &format!("{preset}/loss_multi (K=11 SPSA batch)"),
                None,
                &mut || {
                    lm.run1(&[&phis, &xr]).unwrap();
                },
            );
            record(&mut rep, runs);
        }
        if let Ok(val) = rt.entry(preset, "validate") {
            let runs = measure(&format!("{preset}/validate (B=1024)"), None, &mut || {
                val.run_scalar(&[&phi, &xv, &uv]).unwrap();
            });
            record(&mut rep, runs);
        }
    }

    // per-dispatch EvalOptions vs the old global-state path: the same
    // engine config resolved once from the backend default (`run`) and
    // once carried by every dispatch (`run_with`). The per-dispatch
    // path joins the enforce gate below: CI fails if options travelling
    // with the dispatch cost measurable latency over the global path.
    {
        let preset = "tonn_small";
        if rt.manifest().preset(preset).is_ok() {
            let pm = rt.manifest().preset(preset).unwrap();
            let (warm, iters) = if fast { (1, 5) } else { (3, 20) };
            let mut rng = Rng::new(5);
            let phi = pm.layout.init_vector(&mut rng);
            let mut sampler = Sampler::new(pm.pde.clone(), 6);
            let mut xr = Vec::new();
            sampler.batch(rt.manifest().b_residual, &mut xr);
            let loss = rt.entry(preset, "loss").unwrap();
            rt.set_parallel(par_cfg);
            let global = bench(
                &format!("{preset}/loss opts backend-default"),
                warm,
                iters,
                || {
                    loss.run_scalar(&[&phi, &xr]).unwrap();
                },
            );
            let opts = EvalOptions::NONE.with_parallel(par_cfg);
            let perdisp = bench(
                &format!("{preset}/loss opts per-dispatch"),
                warm,
                iters,
                || {
                    loss.run_scalar_with(&[&phi, &xr], &opts).unwrap();
                },
            );
            rep.case_vs(&global, None);
            rep.case_vs(&perdisp, Some(&global));
            enforced.push((perdisp.name.clone(), perdisp.median_s, global.median_s));
            results.push(global);
            results.push(perdisp);
        }
    }

    // train_throughput: full ZO training epochs through the probe-
    // parallel batched loss path vs the 1-thread sequential engine
    // (epochs/s is THE number the paper's "real-time" claim cares
    // about). The parallel case joins the enforce gate below: CI fails
    // if probe-parallel training is slower than sequential.
    {
        let preset = "tonn_small";
        if rt.manifest().preset(preset).is_ok() {
            let epochs = if fast { 3 } else { 12 };
            let iters = if fast { 3 } else { 5 };
            let mut cfg = TrainConfig::from_manifest(&rt, preset).unwrap();
            cfg.epochs = epochs;
            cfg.seed = 1;
            cfg.validate_every = 0;
            cfg.verbose = false;
            let mut final_val = 0.0f32;
            let mut run = |par: ParallelConfig, label: &str| {
                rt.set_parallel(par);
                bench(
                    &format!("train/{preset} {epochs}ep {label}"),
                    1,
                    iters,
                    || {
                        let res = OnChipTrainer::new(&rt, cfg.clone())
                            .unwrap()
                            .train()
                            .unwrap();
                        final_val = res.final_val;
                    },
                )
            };
            let seq = run(seq_cfg, "engine seq(1T)");
            let par = run(par_cfg, &format!("engine par({}T)", par_cfg.threads));
            rep.case_vs(&seq, None);
            rep.case_vs(&par, Some(&seq));
            rep.case_raw_with(
                &format!("train_throughput/{preset}"),
                par.median_s,
                &[
                    ("epochs_per_s_par", epochs as f64 / par.median_s),
                    ("epochs_per_s_seq", epochs as f64 / seq.median_s),
                    ("final_val", final_val as f64),
                ],
            );
            enforced.push((par.name.clone(), par.median_s, seq.median_s));
            results.push(seq);
            results.push(par);
        }
    }

    // pool vs scoped dispatch driver: the persistent work-stealing pool
    // against the retained scoped-thread oracle (PHOTON_FORCE_SCOPED=1)
    // on the same probe-parallel workload, per gated preset size, plus a
    // full training run. Results are bit-identical by construction; the
    // pool cases join the enforce gate below, so CI fails if routing
    // dispatches through the persistent pool is ever slower than
    // spawning fresh scoped threads per dispatch.
    {
        use photon_pinn::runtime::pool;
        rt.set_parallel(par_cfg);
        for preset in presets {
            let Ok(pm) = rt.manifest().preset(preset) else { continue };
            if preset.contains("micro") {
                continue; // below the enforce gate's work floor
            }
            let Ok(lm) = rt.entry(preset, "loss_multi") else { continue };
            let (warm, iters) = match (fast, *preset) {
                (true, _) => (1, 5),
                (false, "tonn_paper") => (1, 5),
                (false, _) => (3, 20),
            };
            let mut rng = Rng::new(21);
            let phi = pm.layout.init_vector(&mut rng);
            let k = rt.manifest().k_multi;
            let phis: Vec<f32> = (0..k).flat_map(|_| phi.iter().copied()).collect();
            let mut sampler = Sampler::new(pm.pde.clone(), 22);
            let mut xr = Vec::new();
            sampler.batch(rt.manifest().b_residual, &mut xr);
            pool::set_force_scoped(true);
            let scoped = bench(
                &format!("{preset}/loss_multi driver scoped({}T)", par_cfg.threads),
                warm,
                iters,
                || {
                    lm.run1(&[&phis, &xr]).unwrap();
                },
            );
            pool::set_force_scoped(false);
            let pooled = bench(
                &format!("{preset}/loss_multi driver pool({}T)", par_cfg.threads),
                warm,
                iters,
                || {
                    lm.run1(&[&phis, &xr]).unwrap();
                },
            );
            rep.case_vs(&scoped, None);
            rep.case_vs(&pooled, Some(&scoped));
            enforced.push((pooled.name.clone(), pooled.median_s, scoped.median_s));
            results.push(scoped);
            results.push(pooled);
        }
        // the acceptance number: whole training epochs, pool vs scoped
        let preset = "tonn_small";
        if rt.manifest().preset(preset).is_ok() {
            let epochs = if fast { 3 } else { 12 };
            let iters = if fast { 3 } else { 5 };
            let mut cfg = TrainConfig::from_manifest(&rt, preset).unwrap();
            cfg.epochs = epochs;
            cfg.seed = 1;
            cfg.validate_every = 0;
            cfg.verbose = false;
            let mut run = |label: &str| {
                bench(&format!("train/{preset} {epochs}ep {label}"), 1, iters, || {
                    OnChipTrainer::new(&rt, cfg.clone()).unwrap().train().unwrap();
                })
            };
            pool::set_force_scoped(true);
            let scoped = run("driver scoped");
            pool::set_force_scoped(false);
            let pooled = run("driver pool");
            rep.case_vs(&scoped, None);
            rep.case_vs(&pooled, Some(&scoped));
            rep.case_raw_with(
                &format!("train_throughput/{preset} pool-vs-scoped"),
                pooled.median_s,
                &[
                    ("epochs_per_s_pool", epochs as f64 / pooled.median_s),
                    ("epochs_per_s_scoped", epochs as f64 / scoped.median_s),
                ],
            );
            enforced.push((pooled.name.clone(), pooled.median_s, scoped.median_s));
            results.push(scoped);
            results.push(pooled);
        }
        // leave the driver as the environment requested it
        pool::set_force_scoped(std::env::var("PHOTON_FORCE_SCOPED").as_deref() == Ok("1"));
    }

    // precision tiers (their own "precision" report section): the f64
    // oracle tier is the baseline; the default f32 engine and the
    // 16-bit quantized tier are measured against it, each case carrying
    // its loss value and |delta| vs the oracle so the report records
    // the speed/accuracy trade in one place. The f32-vs-f64 pair joins
    // the enforce gate: reduced precision must never be SLOWER than the
    // oracle on a gated-size dispatch.
    let mut prep = BenchReport::new(
        "precision",
        "native-cpu",
        par_cfg.threads,
        par_cfg.block_rows,
    );
    {
        let preset = "tonn_small";
        if let Ok(pm) = rt.manifest().preset(preset) {
            let (warm, iters) = if fast { (1, 5) } else { (3, 20) };
            let mut rng = Rng::new(8);
            let phi = pm.layout.init_vector(&mut rng);
            let mut sampler = Sampler::new(pm.pde.clone(), 12);
            let mut xr = Vec::new();
            sampler.batch(rt.manifest().b_residual, &mut xr);
            let loss = rt.entry(preset, "loss").unwrap();
            rt.set_parallel(par_cfg);
            println!(
                "\nprecision tiers on {preset}/loss (kernel path: {})",
                simd::kernel_path()
            );

            let tiers = [
                ("f64", EvalPrecision::F64),
                ("f32", EvalPrecision::F32),
                ("q16", EvalPrecision::Quantized { bits: 16 }),
            ];
            let mut runs: Vec<(BenchResult, f32)> = Vec::new();
            for (name, tier) in tiers {
                let o = EvalOptions::NONE.with_precision(tier);
                let l = loss.run_scalar_with(&[&phi, &xr], &o).unwrap();
                let r = bench(
                    &format!("{preset}/loss precision {name}"),
                    warm,
                    iters,
                    || {
                        loss.run_scalar_with(&[&phi, &xr], &o).unwrap();
                    },
                );
                runs.push((r, l));
            }
            let l64 = runs[0].1 as f64;
            for (i, (r, l)) in runs.iter().enumerate() {
                let base = if i == 0 { None } else { Some(&runs[0].0) };
                prep.case_vs(r, base);
                let c = prep.cases.last_mut().unwrap();
                c.extra.push(("loss".to_string(), *l as f64));
                c.extra
                    .push(("loss_delta_vs_f64".to_string(), (*l as f64 - l64).abs()));
            }
            // f32 (the default engine) gated against the f64 oracle
            enforced.push((
                runs[1].0.name.clone(),
                runs[1].0.median_s,
                runs[0].0.median_s,
            ));
            for (r, _) in runs {
                results.push(r);
            }
        }
    }

    // L3-side costs: everything the coordinator does *around* a dispatch
    {
        let pm = rt.manifest().preset("tonn_small").unwrap();
        let d = pm.layout.param_dim;
        let chip = ChipRealization::sample(&pm.layout, &NoiseConfig::default_chip(), 1);
        let spsa = Spsa::new(0.02, 10);
        let mut rng = Rng::new(2);
        let phi = pm.layout.init_vector(&mut rng);
        let (mut xi, mut settings, mut eff, mut eff_all, mut grad) =
            (Vec::new(), Vec::new(), Vec::new(), Vec::new(), Vec::new());
        results.push(bench("L3/perturb+program (K=11, d=473)", 10, 200, || {
            spsa.sample_perturbations(d, &mut rng, &mut xi);
            spsa.build_settings(&phi, &xi, &mut settings);
            eff_all.clear();
            for i in 0..11 {
                chip.program(&settings[i * d..(i + 1) * d], &mut eff);
                eff_all.extend_from_slice(&eff);
            }
            std::hint::black_box(&eff_all);
        }));
        let losses = vec![0.5f32; 11];
        let xi2 = {
            let mut v = vec![0.0f32; 10 * d];
            Rng::new(3).fill_normal(&mut v);
            v
        };
        results.push(bench("L3/spsa estimate + sign step", 10, 500, || {
            spsa.estimate(&losses, &xi2, &mut grad);
            std::hint::black_box(&grad);
        }));
        let mut sampler = Sampler::new(pm.pde.clone(), 9);
        let mut xr = Vec::new();
        results.push(bench("L3/sample collocation batch (100x21)", 10, 500, || {
            sampler.batch(100, &mut xr);
            std::hint::black_box(&xr);
        }));
        let n = results.len();
        for r in &results[n - 3..] {
            rep.case(r);
        }
    }

    report(&results);
    println!("\nL3 overhead per training step = perturb+program + estimate + sampling;");
    println!("compare against the loss_multi dispatch above (DESIGN.md §Perf target: <10%).");

    let path = bench_report_path();
    if let Err(e) = rep.write_merged(&path) {
        eprintln!("cannot write {}: {e:#}", path.display());
        std::process::exit(2);
    }
    if let Err(e) = prep.write_merged(&path) {
        eprintln!("cannot write {}: {e:#}", path.display());
        std::process::exit(2);
    }
    println!(
        "\nperf report merged into {} ({} + {} cases, engine {}Tx{} rows/block)",
        path.display(),
        rep.cases.len(),
        prep.cases.len(),
        rep.threads,
        rep.block_rows
    );
    if let Some(s) = rep.min_speedup() {
        println!("min recorded speedup vs baseline: {s:.2}x");
    }

    if std::env::var("PHOTON_BENCH_ENFORCE").as_deref() == Ok("1") {
        // gate only dispatches with enough sequential work to swamp the
        // per-dispatch thread spawn cost, and give shared CI runners a
        // 10% noise margin on 5-sample medians
        const MIN_GATED_SEQ_S: f64 = 1e-3;
        const NOISE_MARGIN: f64 = 1.10;
        let mut gated = 0usize;
        let mut skipped = 0usize;
        let mut failures: Vec<String> = Vec::new();
        for (name, p, s) in &enforced {
            if *s < MIN_GATED_SEQ_S {
                skipped += 1;
                continue;
            }
            gated += 1;
            if *p > s * NOISE_MARGIN {
                failures.push(format!(
                    "{name}: {:.3}ms > baseline {:.3}ms (+10% margin)",
                    p * 1e3,
                    s * 1e3
                ));
            }
        }
        if failures.is_empty() {
            println!(
                "enforce: no gated case slower than its baseline, {gated} gated \
                 ({skipped} below the {MIN_GATED_SEQ_S}s work floor)"
            );
        } else {
            for f in &failures {
                eprintln!("enforce FAILED: {f}");
            }
            std::process::exit(1);
        }
    }
}
