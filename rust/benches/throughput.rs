//! Bench: solver-service aggregate throughput (jobs/s) under queue
//! depth, fused vs unfused dispatches. A backlog of same-preset jobs is
//! submitted and drained twice — once with gang fusion at the default
//! width (same-preset jobs share one `loss_fused` engine pass per
//! epoch) and once with `fuse_max = 1` (every job dispatches alone, the
//! pre-scheduler behavior). Fusion changes LATENCY ONLY: both drains
//! produce bit-identical Φ/val per job (`tests/service_scheduler.rs`).
//! All worker threads fan their engine passes out on the ONE shared
//! evaluation pool (`runtime::pool`); its counters are recorded as a
//! `pool_totals` case so steal/occupancy behavior under a multi-worker
//! backlog stays visible across PRs.
//! Every case merges into `BENCH_native.json` (schema:
//! `util::bench::BenchReport`) so perf is comparable across PRs.
//!
//!     cargo bench --bench throughput
//!
//! Environment knobs:
//! * `PHOTON_BENCH_FAST=1`    — small backlogs, CI smoke depths
//! * `PHOTON_THREADS=N`       — engine threads (via ParallelConfig::auto)
//! * `PHOTON_BENCH_ENFORCE=1` — exit non-zero if the fused drain is
//!   slower than the unfused drain at the gated depth (+noise margin)
//! * `PHOTON_BENCH_OUT=path`  — report location (default: repo root)

mod common;

use std::sync::Arc;

use photon_pinn::coordinator::{ServiceConfig, SolveRequest, SolverService, TrainConfig};
use photon_pinn::runtime::{Backend, NativeBackend, ParallelConfig};
use photon_pinn::util::bench::{bench, bench_report_path, report, BenchReport, BenchResult};

const PRESET: &str = "tonn_micro";
const WORKERS: usize = 2;
const EPOCHS: usize = 3;
/// shared-CI-runner tolerance on the enforce gate (same as latency.rs)
const NOISE_MARGIN: f64 = 1.10;

/// Submit a `depth`-job same-preset backlog and drain it; returns once
/// every result arrived OK. The measured window is submit → last recv
/// (service startup + warmup stay outside).
fn drain(be: &Arc<dyn Backend + Send + Sync>, cfg: &TrainConfig, depth: usize, fuse_max: usize) {
    let svc = SolverService::start_shared(
        be.clone(),
        ServiceConfig::new(WORKERS, depth)
            .with_warmup(PRESET)
            .with_parallel(ParallelConfig::auto())
            .with_fuse_max(fuse_max),
    );
    for id in 0..depth as u64 {
        let mut config = cfg.clone();
        config.seed = 1000 + id;
        svc.submit(SolveRequest { id, config }).unwrap();
    }
    for _ in 0..depth {
        let r = svc.recv().unwrap();
        r.final_val.unwrap_or_else(|e| panic!("job {} failed: {e:#}", r.id));
    }
    let rest = svc.shutdown();
    assert!(rest.is_empty(), "drained everything before shutdown");
}

fn main() {
    let fast = common::fast();
    let dir = photon_pinn::resolve_artifacts_dir(None);
    let be: Arc<dyn Backend + Send + Sync> = match NativeBackend::load_or_builtin(&dir) {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            eprintln!("cannot load native backend from {}: {e:#}", dir.display());
            std::process::exit(2);
        }
    };
    let mut cfg = match TrainConfig::from_manifest(be.as_ref(), PRESET) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("no '{PRESET}' preset: {e:#}");
            std::process::exit(2);
        }
    };
    cfg.epochs = EPOCHS;
    cfg.validate_every = 0;
    cfg.verbose = false;

    // queued-backlog depths; the gated depth is the 100-job (smoke:
    // 30-job) drain — deep enough for gangs to form steadily, shallow
    // enough for repeated medians
    let depths: &[usize] = if fast { &[10, 30] } else { &[10, 100, 1000] };
    let gated_depth = if fast { 30 } else { 100 };
    let fused_width = ServiceConfig::DEFAULT_FUSE_MAX;

    let par = ParallelConfig::auto();
    let mut results: Vec<BenchResult> = Vec::new();
    let mut rep = BenchReport::new("throughput", "native-cpu", par.threads, par.block_rows);
    let mut gate: Option<(BenchResult, BenchResult)> = None;

    for &depth in depths {
        let (warm, iters) = if depth >= 1000 { (0, 1) } else { (1, 3) };
        let unfused = bench(
            &format!("service/{PRESET} jobs={depth} unfused(g=1)"),
            warm,
            iters,
            || drain(&be, &cfg, depth, 1),
        );
        let fused = bench(
            &format!("service/{PRESET} jobs={depth} fused(g={fused_width})"),
            warm,
            iters,
            || drain(&be, &cfg, depth, fused_width),
        );
        rep.case_vs(&unfused, None);
        rep.case_vs(&fused, Some(&unfused));
        rep.case_raw_vs(
            &format!("service/{PRESET} jobs={depth} aggregate"),
            fused.median_s,
            unfused.median_s,
            &[
                ("jobs_per_s_fused", depth as f64 / fused.median_s),
                ("jobs_per_s_unfused", depth as f64 / unfused.median_s),
            ],
        );
        if depth == gated_depth {
            gate = Some((fused.clone(), unfused.clone()));
        }
        results.push(unfused);
        results.push(fused);
    }

    // what the shared worker pool did while {WORKERS} service workers
    // drained the backlogs above (process-wide totals)
    let snap = photon_pinn::util::telemetry::snapshot();
    rep.case_raw_with(
        &format!("service/{PRESET} pool_totals (telemetry)"),
        0.0,
        &[
            ("pool_dispatches", snap.pool.dispatches as f64),
            ("pool_tasks_executed", snap.pool.tasks_executed as f64),
            ("pool_tasks_stolen", snap.pool.tasks_stolen as f64),
            ("pool_queue_depth_hwm", snap.pool.queue_depth_hwm as f64),
            ("pool_lane_width_hwm", snap.pool.lane_width_hwm as f64),
        ],
    );

    report(&results);
    println!(
        "\naggregate throughput: {WORKERS} workers, {EPOCHS}-epoch {PRESET} jobs; fused drains"
    );
    println!("merge each epoch's probe dispatches across a gang of <= {fused_width} jobs.");
    println!(
        "shared pool ({}): {} fan-outs, {} tasks executed + {} stolen, queue hwm {}",
        snap.pool.driver,
        snap.pool.dispatches,
        snap.pool.tasks_executed,
        snap.pool.tasks_stolen,
        snap.pool.queue_depth_hwm,
    );

    let path = bench_report_path();
    if let Err(e) = rep.write_merged(&path) {
        eprintln!("cannot write {}: {e:#}", path.display());
        std::process::exit(2);
    }
    println!(
        "\nperf report merged into {} ({} cases, engine {}Tx{} rows/block)",
        path.display(),
        rep.cases.len(),
        rep.threads,
        rep.block_rows
    );

    if std::env::var("PHOTON_BENCH_ENFORCE").as_deref() == Ok("1") {
        let (fused, unfused) = gate.expect("gated depth is always measured");
        if fused.median_s > unfused.median_s * NOISE_MARGIN {
            eprintln!(
                "enforce FAILED: fused drain {:.1}ms > unfused {:.1}ms (+10% margin) \
                 at {gated_depth} queued jobs",
                fused.median_s * 1e3,
                unfused.median_s * 1e3
            );
            std::process::exit(1);
        }
        println!(
            "enforce: fused >= unfused jobs/s at {gated_depth} queued jobs \
             ({:.1} vs {:.1} jobs/s)",
            gated_depth as f64 / fused.median_s,
            gated_depth as f64 / unfused.median_s
        );
    }
}
