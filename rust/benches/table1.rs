//! Bench T1: regenerate the paper's Table 1 — validation loss of
//! {ONN, TONN} x {off-chip w/o noise, off-chip w/ noise, on-chip (ours)}
//! on the 20-dim HJB PDE, at the CPU reproduction scale (DESIGN.md
//! §Scale: n=64 instead of 1024, proportionally fewer epochs; the
//! qualitative shape is the claim under test).
//!
//!     cargo bench --bench table1
//!     PHOTON_BENCH_FAST=1 cargo bench --bench table1   (smoke)

mod common;

use photon_pinn::coordinator::experiment::{Table1Config, Table1Runner};
use photon_pinn::photonics::noise::NoiseConfig;
use photon_pinn::runtime::Backend;
use photon_pinn::util::bench::{bench_report_path, BenchReport, Table};
use photon_pinn::util::stats::sci;

fn main() {
    let rt = common::runtime();
    let cfg = Table1Config {
        zo_epochs: common::epochs(1500),
        bp_epochs: common::epochs(400),
        noise: NoiseConfig::default_chip(),
        chip_seed: 11,
        aware_seed: 177,
        seed: 0,
        verbose: false,
    };
    println!(
        "running Table 1 matrix (zo_epochs={}, bp_epochs={}) ...",
        cfg.zo_epochs, cfg.bp_epochs
    );
    let runner = Table1Runner { rt: &rt, cfg };

    let mut t = Table::new(
        "Table 1 — paper vs measured (reproduction scale n=64)",
        &["Network", "Params(Φ)", "Off. w/o noise", "Off. w/ noise", "On. w/ noise (ours)"],
    );
    // the paper's full-scale numbers, for the side-by-side
    t.row(&["ONN (paper n=1024)".into(), "608257".into(),
            "3.10e-1 (7.63e-3)".into(), "3.07e-1 (7.81e-3)".into(), "1.43e-2".into()]);
    t.row(&["TONN (paper n=1024)".into(), "1536".into(),
            "3.73e-1 (1.46e-2)".into(), "2.97e-1 (1.35e-2)".into(), "5.53e-3".into()]);

    let par = runner.rt.parallel();
    let mut rep = BenchReport::new("table1", &runner.rt.platform(), par.threads, par.block_rows);
    let mut rows = Vec::new();
    for preset in ["onn_small", "tonn_small"] {
        let t0 = std::time::Instant::now();
        // the off-chip rows need the `grad` entry (AOT artifacts / pjrt
        // build); on the native backend explain instead of panicking
        let row = match runner.run_preset(preset) {
            Ok(row) => row,
            Err(e) => {
                eprintln!("  {preset}: skipped ({e:#})");
                rep.case_raw(
                    &format!("table1/{preset} skipped (no grad entry)"),
                    t0.elapsed().as_secs_f64(),
                );
                continue;
            }
        };
        eprintln!("  {preset} done in {:.0}s", t0.elapsed().as_secs_f64());
        rep.case_raw(
            &format!("table1/{preset} wall"),
            t0.elapsed().as_secs_f64(),
        );
        t.row(&[
            format!("{} (measured)", row.network),
            row.params.to_string(),
            format!("{} ({})", sci(row.off_no_noise.0 as f64), sci(row.off_no_noise.1 as f64)),
            format!("{} ({})", sci(row.off_with_noise.0 as f64), sci(row.off_with_noise.1 as f64)),
            sci(row.on_with_noise as f64),
        ]);
        rows.push(row);
    }
    t.print();

    println!("\nshape checks (the paper's qualitative claims):");
    for row in &rows {
        let mapped = row.off_no_noise.0;
        let ideal = row.off_no_noise.1;
        let on = row.on_with_noise;
        println!(
            "  {}: mapping degrades off-chip by {:.0}x (paper ~40x) | on-chip beats mapped by {:.0}x",
            row.network,
            mapped / ideal.max(1e-9),
            mapped / on.max(1e-9)
        );
    }
    if rows.len() == 2 {
        println!(
            "  TONN on-chip {} ONN on-chip ({} vs {}) — paper: TONN wins (5.53e-3 vs 1.43e-2)",
            if rows[1].on_with_noise < rows[0].on_with_noise { "beats" } else { "does NOT beat" },
            sci(rows[1].on_with_noise as f64),
            sci(rows[0].on_with_noise as f64),
        );
        println!(
            "  parameter reduction TONN vs ONN: {:.0}x (paper: 396x at n=1024)",
            rows[0].params as f64 / rows[1].params as f64
        );
    }

    let path = bench_report_path();
    match rep.write_merged(&path) {
        Ok(()) => println!("\nwall-time report merged into {}", path.display()),
        Err(e) => eprintln!("cannot write {}: {e:#}", path.display()),
    }
}
