//! Bench: scenario sweep — train EVERY problem registered in the `pde`
//! registry for a fixed fast budget, through the shared-backend solver
//! service, and merge per-problem loss/latency rows into
//! `BENCH_native.json` (report section `scenario_sweep`). This is the
//! cross-PR record of how the whole scenario suite behaves as the
//! registry grows.
//!
//!     cargo bench --bench scenario_sweep
//!
//! Environment knobs:
//! * `PHOTON_BENCH_FAST=1` — smoke budget (CI's scenario-suite step)
//! * `PHOTON_THREADS=N`    — evaluation-engine threads
//! * `PHOTON_BENCH_OUT`    — report location (default: repo root)
//!
//! The bench exits non-zero when a registered problem has no trainable
//! preset or a solve fails — the registry and the preset table may not
//! drift apart silently.

mod common;

use std::collections::HashMap;
use std::sync::Arc;

use photon_pinn::coordinator::{ServiceConfig, SolveRequest, SolverService, TrainConfig};
use photon_pinn::pde::Problem;
use photon_pinn::photonics::noise::NoiseConfig;
use photon_pinn::runtime::{Backend, NativeBackend, ParallelConfig};
use photon_pinn::util::bench::{bench_report_path, BenchReport, Table};

fn main() {
    let fast = common::fast();
    let epochs = if fast { 15 } else { 200 };
    let be: Arc<NativeBackend> = Arc::new(NativeBackend::builtin());

    // smallest trainable preset per registered problem (deterministic:
    // presets scanned in sorted-name order, strict param_dim improvement)
    let mut preset_names: Vec<&String> = be.manifest().presets.keys().collect();
    preset_names.sort();
    let mut pick: HashMap<String, String> = HashMap::new();
    for pname in preset_names {
        let pm = &be.manifest().presets[pname];
        if !pm.entries.contains_key("loss_multi") || !pm.entries.contains_key("validate") {
            continue;
        }
        let prob = pm.pde.name().to_string();
        let better = match pick.get(&prob) {
            Some(cur) => pm.layout.param_dim < be.manifest().presets[cur].layout.param_dim,
            None => true,
        };
        if better {
            pick.insert(prob, pname.clone());
        }
    }
    let uncovered: Vec<String> = photon_pinn::pde::registry()
        .problems()
        .filter(|p| !pick.contains_key(p.name()))
        .map(|p| p.name().to_string())
        .collect();
    if !uncovered.is_empty() {
        eprintln!(
            "scenario sweep FAILED: registered problems with no trainable preset: {}",
            uncovered.join(", ")
        );
        std::process::exit(1);
    }

    let par = ParallelConfig::auto();
    let workers = if fast { 2 } else { 4 };
    let service = SolverService::start_shared(
        be.clone(),
        ServiceConfig::new(workers, 2 * pick.len()).with_parallel(par),
    );

    let mut jobs: Vec<(u64, String, String)> = Vec::new();
    let mut sorted: Vec<(String, String)> = pick.into_iter().collect();
    sorted.sort();
    for (id, (prob, preset)) in sorted.into_iter().enumerate() {
        let mut cfg = TrainConfig::from_manifest(be.as_ref(), &preset)
            .expect("preset has tuned hyperparameters");
        cfg.epochs = epochs;
        cfg.seed = 0;
        cfg.noise = NoiseConfig::default_chip();
        cfg.validate_every = 0;
        cfg.verbose = false;
        service
            .submit(SolveRequest {
                id: id as u64,
                config: cfg,
            })
            .expect("service accepts the sweep");
        jobs.push((id as u64, prob, preset));
    }
    let mut results = HashMap::new();
    for _ in 0..jobs.len() {
        let r = service.recv().expect("service yields every solve");
        results.insert(r.id, r);
    }
    service.shutdown();

    let par = be.parallel();
    let mut rep = BenchReport::new("scenario_sweep", "native-cpu", par.threads, par.block_rows);
    let mut t = Table::new(
        &format!("scenario sweep ({epochs} epochs, default chip noise, {workers} workers)"),
        &[
            "problem",
            "preset",
            "params",
            "dim",
            "stencil",
            "final val MSE",
            "solve (s)",
            "epoch/s",
        ],
    );
    let mut failures = 0usize;
    for (id, prob, preset) in &jobs {
        let r = &results[id];
        let pm = be.manifest().preset(preset).unwrap();
        match &r.final_val {
            Ok(v) => {
                rep.case_raw_with(
                    &format!("{prob}/{preset} train({epochs}ep)"),
                    r.solve_seconds,
                    &[("final_val", *v as f64), ("epochs", epochs as f64)],
                );
                t.row(&[
                    prob.clone(),
                    preset.clone(),
                    pm.layout.param_dim.to_string(),
                    pm.pde.dim().to_string(),
                    pm.pde.n_stencil().to_string(),
                    format!("{v:.3e}"),
                    format!("{:.2}", r.solve_seconds),
                    format!("{:.1}", epochs as f64 / r.solve_seconds.max(1e-9)),
                ]);
            }
            Err(e) => {
                failures += 1;
                eprintln!("{prob}/{preset}: solve FAILED: {e:#}");
            }
        }
    }
    t.print();

    let path = bench_report_path();
    if let Err(e) = rep.write_merged(&path) {
        eprintln!("cannot write {}: {e:#}", path.display());
        std::process::exit(2);
    }
    println!(
        "\nscenario_sweep report merged into {} ({} problems, engine {}Tx{} rows/block)",
        path.display(),
        rep.cases.len(),
        rep.threads,
        rep.block_rows
    );
    if failures > 0 {
        eprintln!("scenario sweep FAILED: {failures} problem(s) did not solve");
        std::process::exit(1);
    }
}
