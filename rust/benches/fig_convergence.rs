//! Bench F1: convergence curves — loss & validation vs epoch for
//! on-chip ZO training of TONN vs ONN, plus the off-chip BP reference.
//!
//! The paper's claims under test: "the tensor-compressed format...
//! improves the convergence of the ZO training framework" (§3.3) and
//! "on average training reaches a good solution after 5000 epochs"
//! (§4.2, full scale).
//!
//! Emits bench_out/fig_convergence.csv (epoch, series, loss, val).
//!
//!     cargo bench --bench fig_convergence

mod common;

use photon_pinn::coordinator::offchip::{OffChipConfig, OffChipTrainer};
use photon_pinn::coordinator::trainer::{OnChipTrainer, TrainConfig};
use photon_pinn::photonics::noise::NoiseConfig;

fn main() {
    let rt = common::runtime();
    let epochs = common::epochs(800);
    let mut csv = String::from("series,epoch,loss,val\n");

    for preset in ["tonn_small", "onn_small"] {
        let mut cfg = TrainConfig::from_manifest(&rt, preset).unwrap();
        cfg.epochs = epochs;
        cfg.validate_every = 25;
        cfg.noise = NoiseConfig::default_chip();
        let t0 = std::time::Instant::now();
        let res = OnChipTrainer::new(&rt, cfg).unwrap().train().unwrap();
        println!(
            "{preset} ZO: final val {:.3e} ({:.0}s, {} epochs)",
            res.final_val,
            t0.elapsed().as_secs_f64(),
            epochs
        );
        for r in &res.metrics.records {
            csv.push_str(&format!(
                "zo_{preset},{},{},{}\n",
                r.epoch,
                r.loss,
                r.val.map(|v| v.to_string()).unwrap_or_default()
            ));
        }
    }

    // off-chip BP reference curve (ideal hardware). Needs the `grad`
    // entry — only available from AOT artifacts (pjrt builds); the
    // native backend reports that loudly, so skip the series there.
    let mut ocfg = OffChipConfig::new("tonn_small", common::epochs(400));
    ocfg.validate_every = 25;
    match OffChipTrainer::new(&rt, ocfg) {
        Ok(mut tr) => {
            let (_, ideal, metrics) = tr.train().unwrap();
            println!("tonn_small BP (ideal): final val {ideal:.3e}");
            for r in &metrics.records {
                csv.push_str(&format!(
                    "bp_tonn_small,{},{},{}\n",
                    r.epoch,
                    r.loss,
                    r.val.map(|v| v.to_string()).unwrap_or_default()
                ));
            }
        }
        Err(e) => println!("skipping BP reference series: {e:#}"),
    }

    let path = common::out_dir().join("fig_convergence.csv");
    std::fs::write(&path, csv).unwrap();
    println!("curves written to {}", path.display());
    println!("\nshape check: the TONN ZO curve should reach a lower plateau than ONN ZO");
}
