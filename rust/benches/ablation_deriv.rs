//! Bench A4: BP-free derivative estimator — finite differences vs the
//! Stein (Gaussian-smoothing) estimator (paper §3.3 lists both).
//!
//!     cargo bench --bench ablation_deriv

mod common;

use photon_pinn::coordinator::trainer::{LossKind, OnChipTrainer, TrainConfig};
use photon_pinn::pde::Problem;
use photon_pinn::runtime::Backend;
use photon_pinn::util::bench::Table;
use photon_pinn::util::stats::sci;

fn main() {
    let rt = common::runtime();
    let epochs = common::epochs(400);
    let pm = rt.manifest().preset("tonn_small").unwrap();
    let stein_q = pm
        .entries
        .get("loss_stein")
        .map(|e| e.inputs[2].1[0])
        .unwrap_or(0);
    let mut t = Table::new(
        "A4 — derivative estimator ablation (tonn_small)",
        &["estimator", "inferences/loss-eval", "final val", "best val", "wall s"],
    );
    for (kind, label, cost) in [
        (LossKind::Fd, "finite difference", pm.pde.n_stencil()),
        (LossKind::Stein, "Stein (antithetic)", 2 * stein_q + 1),
    ] {
        let mut cfg = TrainConfig::from_manifest(&rt, "tonn_small").unwrap();
        cfg.epochs = epochs;
        cfg.loss_kind = kind;
        cfg.validate_every = 50;
        let res = OnChipTrainer::new(&rt, cfg).unwrap().train().unwrap();
        t.row(&[
            label.into(),
            cost.to_string(),
            sci(res.final_val as f64),
            sci(res.metrics.best_val().unwrap_or(f32::NAN) as f64),
            format!("{:.0}", res.metrics.wall_seconds),
        ]);
    }
    t.print();
    println!(
        "\npaper §3.3: both estimators are viable BP-free loss evaluations; \
         FD costs 2D+2 = {} inferences, Stein costs 2q+1 = {} here",
        pm.pde.n_stencil(),
        2 * stein_q + 1
    );
}
