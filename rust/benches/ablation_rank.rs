//! Bench A3: TT-rank sweep — trainable parameter count vs ZO-training
//! quality. The paper's variance argument (§3.3): "the tensor-compressed
//! format can dramatically reduce the gradient estimation variance and
//! improve the convergence of the ZO training framework" — so *smaller*
//! ranks should train better under SPSA until expressivity runs out.
//!
//!     cargo bench --bench ablation_rank

mod common;

use photon_pinn::coordinator::trainer::{OnChipTrainer, TrainConfig};
use photon_pinn::runtime::Backend;
use photon_pinn::util::bench::Table;
use photon_pinn::util::stats::sci;

fn main() {
    let rt = common::runtime();
    let epochs = common::epochs(600);
    let mut t = Table::new(
        "A3 — TT-rank ablation (20-dim HJB, ZO on-chip, noisy chip)",
        &["preset", "ranks", "Φ dim", "final val", "best val"],
    );
    let mut csv = String::from("preset,param_dim,final,best\n");
    for (preset, ranks) in [
        ("tonn_rank1", "[1,1,1,1]"),
        ("tonn_small", "[1,2,2,1]"),
        ("tonn_rank4", "[1,4,4,1]"),
        ("onn_small", "dense"),
    ] {
        if rt.manifest().preset(preset).is_err() {
            eprintln!("skipping {preset} (not in manifest)");
            continue;
        }
        let mut cfg = TrainConfig::from_manifest(&rt, preset).unwrap();
        cfg.epochs = epochs;
        cfg.validate_every = 50;
        let d = rt.manifest().preset(preset).unwrap().layout.param_dim;
        let t0 = std::time::Instant::now();
        let res = OnChipTrainer::new(&rt, cfg).unwrap().train().unwrap();
        eprintln!("  {preset} done in {:.0}s", t0.elapsed().as_secs_f64());
        t.row(&[
            preset.into(),
            ranks.into(),
            d.to_string(),
            sci(res.final_val as f64),
            sci(res.metrics.best_val().unwrap_or(f32::NAN) as f64),
        ]);
        csv.push_str(&format!(
            "{preset},{d},{},{}\n",
            res.final_val,
            res.metrics.best_val().unwrap_or(f32::NAN)
        ));
    }
    t.print();
    let path = common::out_dir().join("ablation_rank.csv");
    std::fs::write(&path, csv).unwrap();
    println!("\nshape check: low-rank TONN should beat the dense ONN under equal-epoch ZO training");
    println!("csv: {}", path.display());
}
