//! Bench: hardware report — join the photonic performance model
//! (`photonics::perf::PerfModel`, the engine behind the paper's Table 2)
//! with the telemetry counters of *actually solved* presets, and merge
//! the result into `BENCH_native.json` (report section
//! `hardware_report`).
//!
//! For each preset the bench trains to convergence budget, reads the
//! run's inference/programming counts from its `RunMetrics`, and prices
//! the same workload on the modeled accelerator: modeled energy
//! `J = E_inf x inferences` and modeled latency
//! `s = t_inf x inferences` next to the measured CPU wall time. The
//! paper-scale TONN-1/ONN rows (Table 2 / §4.2) are emitted as fixed
//! anchor rows so the reproduction-scale numbers always sit next to the
//! claims they reproduce, and an `engine_totals` case records the
//! process-wide telemetry snapshot (dispatch counts, cache hit rate)
//! for the whole bench run.
//!
//!     cargo bench --bench hardware_report
//!
//! Environment knobs:
//! * `PHOTON_BENCH_FAST=1` — smoke budget + micro presets (CI)
//! * `PHOTON_THREADS=N`    — evaluation-engine threads
//! * `PHOTON_BENCH_OUT`    — report location (default: repo root)

mod common;

use photon_pinn::coordinator::trainer::{OnChipTrainer, TrainConfig};
use photon_pinn::photonics::perf::{Design, NetworkDims, PerfModel, TrainingEfficiency};
use photon_pinn::runtime::Backend;
use photon_pinn::tensor::TtShape;
use photon_pinn::util::bench::{bench_report_path, BenchReport, Table};
use photon_pinn::util::json::Value;
use photon_pinn::util::telemetry;

/// Map a preset's manifest `arch` block onto the performance model's
/// network description. TONN presets price as TONN-1 (the paper's
/// space+wavelength cascade); dense presets price as ONN.
fn census_dims(arch: &Value) -> Result<(Design, NetworkDims), String> {
    let ty = arch
        .get("type")
        .and_then(|v| v.as_str())
        .ok_or("arch.type missing")?;
    let hidden = arch
        .get("hidden")
        .and_then(|v| v.as_usize())
        .ok_or("arch.hidden missing")?;
    // the paper's WDM budget, capped by the mesh width at micro scales
    let wavelengths = hidden.min(32);
    let usizes = |key: &str| -> Result<Vec<usize>, String> {
        arch.get(key)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| format!("arch.{key} missing"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| format!("arch.{key} entry")))
            .collect()
    };
    match ty {
        "tonn" => {
            let fm = usizes("factors_m")?;
            let fn_ = usizes("factors_n")?;
            let ranks = usizes("ranks")?;
            let tt = TtShape::new(&fm, &fn_, &ranks).map_err(|e| format!("{e:#}"))?;
            Ok((
                Design::Tonn1,
                NetworkDims { hidden, tt: Some(tt), wavelengths },
            ))
        }
        "onn" => Ok((
            Design::Onn,
            NetworkDims { hidden, tt: None, wavelengths },
        )),
        other => Err(format!("unknown arch type '{other}'")),
    }
}

fn sci(v: f64) -> String {
    format!("{v:.2e}")
}

fn main() {
    let fast = common::fast();
    let rt = common::runtime();
    let epochs = common::epochs(200);
    let presets: &[&str] = if fast {
        &["tonn_micro", "tonn_micro_heat"]
    } else {
        &["tonn_small", "onn_small", "tonn_poisson", "tonn_heat"]
    };

    let model = PerfModel::default();
    let par = rt.parallel();
    let mut rep = BenchReport::new("hardware_report", &rt.platform(), par.threads, par.block_rows);
    let mut t = Table::new(
        &format!("hardware report ({epochs} epochs per solve; modeled = paper accelerator)"),
        &[
            "preset",
            "design",
            "MZIs",
            "params",
            "inferences",
            "modeled J",
            "modeled s",
            "measured s",
            "final val",
        ],
    );

    let mut failures = 0usize;
    for preset in presets {
        let pm = match rt.manifest().preset(preset) {
            Ok(pm) => pm,
            Err(e) => {
                eprintln!("{preset}: no such preset: {e:#}");
                failures += 1;
                continue;
            }
        };
        let (design, dims) = match census_dims(&pm.arch) {
            Ok(x) => x,
            Err(e) => {
                eprintln!("{preset}: cannot census arch: {e}");
                failures += 1;
                continue;
            }
        };
        let perf = model.report(design, &dims);

        let mut cfg = match TrainConfig::from_manifest(&rt, preset) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("{preset}: {e:#}");
                failures += 1;
                continue;
            }
        };
        cfg.epochs = epochs;
        cfg.seed = 0;
        cfg.validate_every = 0;
        cfg.verbose = false;
        let t0 = std::time::Instant::now();
        let res = match OnChipTrainer::new(&rt, cfg).and_then(|mut tr| tr.train()) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{preset}: solve FAILED: {e:#}");
                failures += 1;
                continue;
            }
        };
        let wall = t0.elapsed().as_secs_f64();

        let inferences = res.metrics.inferences as f64;
        let modeled_s = perf.latency_per_inference_ns * 1e-9 * inferences;
        let modeled_j = perf.energy_per_inference_j.map(|e| e * inferences);
        let mut extra: Vec<(&str, f64)> = vec![
            ("mzis", perf.mzis as f64),
            ("params", perf.params as f64),
            ("inferences", inferences),
            ("programmings", res.metrics.programmings as f64),
            ("modeled_latency_s", modeled_s),
            ("final_val", res.final_val as f64),
        ];
        // ONN links past the loss budget have no energy figure (paper
        // §4.2: "insurmountable optical loss") — the metric is omitted,
        // not zero
        if let Some(j) = modeled_j {
            extra.push(("modeled_energy_j", j));
        }
        rep.case_raw_with(
            &format!("hardware/{preset} ({})", perf.design),
            wall,
            &extra,
        );
        t.row(&[
            preset.to_string(),
            perf.design.to_string(),
            perf.mzis.to_string(),
            perf.params.to_string(),
            format!("{inferences:.0}"),
            modeled_j.map(sci).unwrap_or_else(|| "n/a".into()),
            sci(modeled_s),
            format!("{wall:.2}"),
            sci(res.final_val as f64),
        ]);
    }

    // fixed paper-scale anchors (Table 2 / §4.2): the full-scale claims
    // the measured rows above reproduce at CPU scale. 0-second rows —
    // nothing is executed, only the model is evaluated.
    let te = TrainingEfficiency::paper();
    for (name, design, dims) in [
        ("paper_tonn", Design::Tonn1, NetworkDims::paper_tonn()),
        ("paper_onn", Design::Onn, NetworkDims::paper_onn()),
    ] {
        let perf = model.report(design, &dims);
        let mut extra: Vec<(&str, f64)> = vec![
            ("mzis", perf.mzis as f64),
            ("params", perf.params as f64),
            ("latency_per_inference_ns", perf.latency_per_inference_ns),
            ("inferences", (te.inferences_per_epoch() * te.epochs) as f64),
        ];
        if let Some(e_inf) = perf.energy_per_inference_j {
            let (e_tot, t_tot) = te.totals(e_inf, perf.latency_per_inference_ns);
            extra.push(("modeled_energy_j", e_tot));
            extra.push(("modeled_latency_s", t_tot));
        }
        rep.case_raw_with(&format!("hardware/{name} ({}) anchor", perf.design), 0.0, &extra);
        t.row(&[
            format!("{name} (anchor)"),
            perf.design.to_string(),
            perf.mzis.to_string(),
            perf.params.to_string(),
            format!("{}", te.inferences_per_epoch() * te.epochs),
            perf.energy_per_inference_j
                .map(|e| sci(te.totals(e, perf.latency_per_inference_ns).0))
                .unwrap_or_else(|| "n/a".into()),
            perf.energy_per_inference_j
                .map(|e| sci(te.totals(e, perf.latency_per_inference_ns).1))
                .unwrap_or_else(|| "-".into()),
            "-".into(),
            "-".into(),
        ]);
    }
    t.print();

    // process-wide engine telemetry for the whole bench run: what the
    // dispatch path actually did while producing the rows above
    let snap = telemetry::snapshot();
    let lookups = snap.engine.mat_cache_hits + snap.engine.mat_cache_misses;
    rep.case_raw_with(
        "hardware/engine_totals (telemetry)",
        0.0,
        &[
            ("dispatches_total", snap.engine.dispatches_total() as f64),
            ("dispatches_f32", snap.engine.dispatches_f32 as f64),
            ("probe_fanouts", snap.engine.probe_fanouts as f64),
            ("probe_lanes", snap.engine.probe_lanes as f64),
            ("mat_cache_hits", snap.engine.mat_cache_hits as f64),
            (
                "mat_cache_hit_rate",
                if lookups > 0 {
                    snap.engine.mat_cache_hits as f64 / lookups as f64
                } else {
                    0.0
                },
            ),
            ("epochs_applied", snap.trainer.epochs_applied as f64),
            ("inferences", snap.trainer.inferences as f64),
            ("pool_dispatches", snap.pool.dispatches as f64),
            ("pool_tasks_executed", snap.pool.tasks_executed as f64),
            ("pool_tasks_stolen", snap.pool.tasks_stolen as f64),
            ("pool_queue_depth_hwm", snap.pool.queue_depth_hwm as f64),
        ],
    );
    println!(
        "\nengine totals: {} dispatches, {} probe fan-outs, cache {}h/{}m (kernel path {})",
        snap.engine.dispatches_total(),
        snap.engine.probe_fanouts,
        snap.engine.mat_cache_hits,
        snap.engine.mat_cache_misses,
        snap.kernel_path,
    );
    println!(
        "worker pool ({}): {} fan-outs, {} tasks executed + {} stolen on {} worker(s)",
        snap.pool.driver,
        snap.pool.dispatches,
        snap.pool.tasks_executed,
        snap.pool.tasks_stolen,
        snap.pool.workers,
    );

    let path = bench_report_path();
    if let Err(e) = rep.write_merged(&path) {
        eprintln!("cannot write {}: {e:#}", path.display());
        std::process::exit(2);
    }
    println!(
        "\nhardware_report merged into {} ({} cases, engine {}Tx{} rows/block)",
        path.display(),
        rep.cases.len(),
        rep.threads,
        rep.block_rows
    );
    if failures > 0 {
        eprintln!("hardware report FAILED: {failures} preset(s) did not price/solve");
        std::process::exit(1);
    }
}
