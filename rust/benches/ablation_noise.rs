//! Bench A2: hardware-noise severity sweep — off-chip-mapped vs on-chip
//! trained validation loss as fabrication noise grows (the robustness
//! mechanism behind Table 1).
//!
//!     cargo bench --bench ablation_noise

mod common;

use photon_pinn::coordinator::offchip::{OffChipConfig, OffChipTrainer};
use photon_pinn::coordinator::trainer::{OnChipTrainer, TrainConfig};
use photon_pinn::photonics::noise::{ChipRealization, NoiseConfig};
use photon_pinn::runtime::Backend;
use photon_pinn::util::bench::Table;
use photon_pinn::util::stats::sci;

fn main() {
    let rt = common::runtime();
    let zo_epochs = common::epochs(600);
    let bp_epochs = common::epochs(300);

    // train ONE off-chip model (noise-free), map it onto chips of
    // increasing imperfection. Needs the `grad` entry (pjrt build).
    let mut off = match OffChipTrainer::new(
        &rt,
        OffChipConfig::new("tonn_small", bp_epochs),
    ) {
        Ok(off) => off,
        Err(e) => {
            eprintln!("A2 needs the off-chip BP baseline: {e:#}");
            std::process::exit(2);
        }
    };
    let (phi_off, ideal, _) = off.train().unwrap();
    println!("off-chip model trained: ideal val {ideal:.3e}");

    let pm = rt.manifest().preset("tonn_small").unwrap();
    let mut t = Table::new(
        "A2 — noise-severity sweep (tonn_small)",
        &["noise scale", "off-chip mapped", "on-chip trained", "on/off advantage"],
    );
    let mut csv = String::from("scale,mapped,onchip\n");
    for scale in [0.0, 0.5, 1.0, 2.0] {
        let noise = NoiseConfig::default_chip().scaled(scale);
        let chip = ChipRealization::sample(&pm.layout, &noise, 11);
        let mapped = off.score_mapped(&phi_off, &chip).unwrap();

        let mut cfg = TrainConfig::from_manifest(&rt, "tonn_small").unwrap();
        cfg.epochs = zo_epochs;
        cfg.noise = noise;
        cfg.chip_seed = 11;
        cfg.validate_every = 0;
        let on = OnChipTrainer::new(&rt, cfg).unwrap().train().unwrap().final_val;
        t.row(&[
            format!("{scale}x"),
            sci(mapped as f64),
            sci(on as f64),
            format!("{:.1}x", mapped / on.max(1e-9)),
        ]);
        csv.push_str(&format!("{scale},{mapped},{on}\n"));
    }
    t.print();
    let path = common::out_dir().join("ablation_noise.csv");
    std::fs::write(&path, csv).unwrap();
    println!("\nshape check: mapped loss grows with noise; on-chip stays near its clean optimum");
    println!("csv: {}", path.display());
}
